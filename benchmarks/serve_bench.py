"""Continuous-batching serving benchmark: ``repro.api.ServeSession`` on a
smoke arch.

Three measurements, one ``BENCH_serve.json``:

  * **throughput / latency** — a request stream served through the slot
    pool; requests/sec, tokens/sec, and p50/p99 per-token decode latency
    (each decoded token inherits its tick's wall time);
  * **parity** — every request is replayed through the sequential
    ``make_serve_step`` reference (``repro.api.sequential_reference``); the
    continuous-batching engine must reproduce tokens AND gate decisions
    exactly, with gate entropies within ``--max-delta`` (the CI serve-smoke
    gate);
  * **adoption-ratio-vs-tau** — the paper's Fig. 2 x-axis: the same request
    stream swept over entropy thresholds.  ``tau`` is a traced runtime
    scalar in the decode step, so the sweep reuses one compilation.

  PYTHONPATH=src python -m benchmarks.serve_bench --max-delta 1e-5
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import numpy as np

from repro import configs as configs_mod
from repro.api.serve_session import (ServeSession, resolve_serve_boundary,
                                     sequential_reference)
from repro.models.backbone import init_backbone

SCHEMA_KEYS = ("benchmark", "config", "throughput", "latency_ms", "parity",
               "adoption_vs_tau")


def _make_prompts(cfg, requests: int, prompt_len: int, seed: int):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, prompt_len)
            for _ in range(requests)]


def _serve(session: ServeSession, prompts, decode_tokens: int):
    """Submit and drain; returns (results by rid, per-token tick latencies)."""
    for p in prompts:
        session.submit(p, decode_tokens=decode_tokens)
    tick_lat: List[float] = []
    while True:
        served_before = session.stats.tokens
        t0 = time.perf_counter()
        more = session.step()
        dt = time.perf_counter() - t0
        tick_lat.extend([dt] * (session.stats.tokens - served_before))
        if not more:
            break
    return {r.rid: r for r in session.results}, tick_lat


def run(arch: str = "glm4-9b", requests: int = 12, slots: int = 4,
        prompt_len: int = 8, decode_tokens: int = 8, tau: float = 2.0,
        boundary: int = 0, num_taus: int = 5, seed: int = 0,
        out: str = "BENCH_serve.json") -> Dict:
    """Serve ``requests`` prompts through a ``slots``-wide ServeSession on
    the ``arch`` smoke config and write the manifest.  Weights are
    seed-initialized — the checkpoint-restore path is covered by
    tests/test_serve_session.py; this bench measures the engine."""
    cfg = configs_mod.get(arch).smoke()
    _, cut, skip_frac = resolve_serve_boundary(cfg, boundary)
    params = init_backbone(jax.random.PRNGKey(seed), cfg)
    max_len = prompt_len + 1 + decode_tokens
    prompts = _make_prompts(cfg, requests, prompt_len, seed + 1)

    session = ServeSession(cfg, params, tau=tau, boundary=boundary,
                           slots=slots, max_len=max_len)
    # warmup: compile prefill + decode step outside the timed window
    session.submit(prompts[0], decode_tokens=decode_tokens)
    session.run()
    session._done.clear()

    t0 = time.perf_counter()
    by_rid, tick_lat = _serve(session, prompts, decode_tokens)
    wall = time.perf_counter() - t0
    lat = np.asarray(tick_lat) * 1e3

    tok_mis = gate_mis = 0
    max_ent_delta = 0.0
    for rid in sorted(by_rid):
        ref = sequential_reference(cfg, params, by_rid[rid].prompt,
                                   decode_tokens, tau=tau,
                                   boundary=boundary, max_len=max_len)
        got = by_rid[rid]
        tok_mis += sum(a != b for a, b in zip(got.tokens, ref.tokens))
        gate_mis += sum(a != b for a, b in zip(got.exited, ref.exited))
        if ref.entropy:
            max_ent_delta = max(max_ent_delta, float(np.max(np.abs(
                np.asarray(got.entropy) - np.asarray(ref.entropy)))))

    # Fig.-2 axis: adoption ratio vs entropy threshold.  Random-init exit
    # entropies sit near ln(V); sweep past it so the curve spans 0 -> 1.
    taus = np.linspace(0.0, 1.1 * np.log(cfg.vocab_size), num_taus)
    sweep = []
    for t in taus:
        session.tau = float(t)       # traced scalar: no recompilation
        session._done.clear()
        sweep_by_rid, _ = _serve(session, prompts, decode_tokens)
        ratio = float(np.mean([r.adoption_ratio
                               for r in sweep_by_rid.values()]))
        sweep.append({"tau": round(float(t), 4),
                      "adoption_ratio": round(ratio, 4)})
    session.tau = tau

    result = {
        "benchmark": "serve_continuous_batching",
        "config": {"arch": cfg.name, "requests": requests, "slots": slots,
                   "prompt_len": prompt_len, "decode_tokens": decode_tokens,
                   "tau": tau, "boundary": boundary, "cut_layer": cut,
                   "skip_frac": round(skip_frac, 4), "max_len": max_len,
                   "exit_policy": session.exit_policy},
        "throughput": {"wall_s": wall,
                       "requests_per_sec": requests / wall,
                       "tokens_per_sec": len(tick_lat) / wall},
        "latency_ms": {"p50": float(np.percentile(lat, 50)),
                       "p99": float(np.percentile(lat, 99)),
                       "mean": float(lat.mean())},
        "parity": {"requests": requests, "token_mismatches": tok_mis,
                   "gate_mismatches": gate_mis,
                   "max_entropy_delta": max_ent_delta},
        "adoption_vs_tau": sweep,
    }
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--decode-tokens", type=int, default=8)
    ap.add_argument("--tau", type=float, default=2.0)
    ap.add_argument("--boundary", type=int, default=0)
    ap.add_argument("--num-taus", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--max-delta", type=float, default=0.0,
                    help="exit non-zero on any token/gate mismatch vs the "
                         "sequential reference, or when the gate-entropy "
                         "delta exceeds this bound (the CI serve-smoke "
                         "gate; 0 disables)")
    args = ap.parse_args()
    r = run(arch=args.arch, requests=args.requests, slots=args.slots,
            prompt_len=args.prompt_len, decode_tokens=args.decode_tokens,
            tau=args.tau, boundary=args.boundary, num_taus=args.num_taus,
            seed=args.seed, out=args.out)

    th, la, pa = r["throughput"], r["latency_ms"], r["parity"]
    print(f"arch={r['config']['arch']} slots={r['config']['slots']} "
          f"tau={r['config']['tau']} boundary={r['config']['boundary']} "
          f"(cut layer {r['config']['cut_layer']})")
    print(f"throughput: {th['requests_per_sec']:.2f} req/s, "
          f"{th['tokens_per_sec']:.1f} tok/s ({th['wall_s']:.2f}s)")
    print(f"latency   : p50 {la['p50']:.1f} ms, p99 {la['p99']:.1f} ms")
    print(f"parity    : {pa['token_mismatches']} token / "
          f"{pa['gate_mismatches']} gate mismatches over "
          f"{pa['requests']} requests, entropy delta "
          f"{pa['max_entropy_delta']:.2e}")
    print("adoption  : " + ", ".join(
        f"tau={s['tau']:.2f}:{s['adoption_ratio']:.2f}"
        for s in r["adoption_vs_tau"]) + f"  -> {args.out}")

    if args.max_delta > 0:
        bad = (pa["token_mismatches"] or pa["gate_mismatches"]
               or pa["max_entropy_delta"] > args.max_delta)
        if bad:
            import sys
            print(f"FAIL: continuous-batching output diverged from the "
                  f"sequential reference (--max-delta {args.max_delta:g})")
            sys.exit(1)
        print(f"parity gate ok (<= {args.max_delta:g})")


if __name__ == "__main__":
    main()
