"""State-space and linear-attention mixers: Mamba2 (SSD) and RWKV6 (Finch).

Both implement the *chunked* parallel form for train/prefill — quadratic
inside a small chunk, linear across chunks via a ``lax.scan`` over chunk
states — and an O(1)-state single-token decode path.  These are the
sub-quadratic mixers that make ``long_500k`` runnable for the SSM/hybrid
assigned architectures.

Mamba2 recurrence (per head, scalar decay a_t = exp(A * dt_t)):
    h_t = a_t * h_{t-1} + dt_t * x_t (outer) B_t        h: (P, S)
    y_t = h_t @ C_t + D * x_t
RWKV6 recurrence (per head, per-key-channel decay w_t in (0,1)):
    S_t = diag(w_t) S_{t-1} + k_t (outer) v_t           S: (K, V)
    y_t = r_t @ (S_{t-1} + diag(u) k_t (outer) v_t)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, SSMConfig
from repro.kernels import dispatch
from repro.models.common import fan_in_init, init_rmsnorm, rmsnorm, ones, zeros

# ---------------------------------------------------------------------------
# Mamba2
# ---------------------------------------------------------------------------


def _mamba_dims(cfg: ModelConfig):
    s: SSMConfig = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.d_state            # xBC go through the conv
    return s, d_inner, nheads, conv_ch


def init_mamba2(rng, cfg: ModelConfig) -> dict:
    s, d_inner, nheads, conv_ch = _mamba_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(rng, 4)
    d_proj = 2 * d_inner + 2 * s.d_state + nheads   # z, xBC, dt
    return {
        "in_proj": fan_in_init(ks[0], (d, d_proj), cfg.param_dtype),
        "conv_w": fan_in_init(ks[1], (s.d_conv, conv_ch), cfg.param_dtype,
                              fan_in=s.d_conv),
        "conv_b": zeros((conv_ch,), cfg.param_dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "dt_bias": zeros((nheads,), jnp.float32),
        "D": ones((nheads,), jnp.float32),
        "out_norm": init_rmsnorm(d_inner, cfg.param_dtype),
        "out_proj": fan_in_init(ks[2], (d_inner, d), cfg.param_dtype),
    }


def init_mamba2_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    s, d_inner, nheads, conv_ch = _mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, nheads, s.head_dim, s.d_state), jnp.float32),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 history: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv1d.  x: (B,T,C), w: (K,C).  ``history`` is the
    (B,K-1,C) tail of the previous tokens (decode) or None (zero-pad)."""
    K = w.shape[0]
    if history is None:
        history = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([history, x], axis=1)              # (B, T+K-1, C)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _mamba2_split(params, x, cfg):
    s, d_inner, nheads, conv_ch = _mamba_dims(cfg)
    proj = jnp.einsum("btd,dp->btp", x, params["in_proj"])
    z = proj[..., :d_inner]
    xBC = proj[..., d_inner : d_inner + conv_ch]
    dt = proj[..., d_inner + conv_ch :]                     # (B,T,H)
    return z, xBC, dt


def _mamba2_core_chunked(xh, B, C, log_a, dt, D, chunk: int):
    """Chunked SSD.  xh: (B,T,H,P), B/C: (B,T,S), log_a: (B,T,H) per-token log
    decay (negative), dt: (B,T,H).  Returns y: (B,T,H,P) and final state
    (B,H,P,S)."""
    Bb, T0, H, P = xh.shape
    S = B.shape[-1]
    Q = min(chunk, T0)
    pad = (-T0) % Q
    if pad:
        # zero-pad: dt=0 and log_a=0 make padded steps identity (decay 1,
        # zero input), so the final state is unaffected.
        pw = ((0, 0), (0, pad), (0, 0), (0, 0))
        xh = jnp.pad(xh, pw)
        B, C = jnp.pad(B, pw[:3]), jnp.pad(C, pw[:3])
        log_a, dt = jnp.pad(log_a, pw[:3]), jnp.pad(dt, pw[:3])
    T = T0 + pad
    nc = T // Q

    def r(t, *shape):  # reshape time into (chunks, Q)
        return t.reshape(t.shape[0], nc, Q, *t.shape[2:])

    xh_c, B_c, C_c = r(xh), r(B), r(C)
    la_c = r(log_a).astype(jnp.float32)                     # (B,nc,Q,H)
    dt_c = r(dt).astype(jnp.float32)
    Lc = jnp.cumsum(la_c, axis=2)                           # within-chunk cumulative
    u = xh_c * dt_c[..., None]                              # weighted input

    # intra-chunk (quadratic in Q): y_t = sum_{i<=t} exp(L_t - L_i) (C_t.B_i) u_i
    scores = jnp.einsum("bnqs,bnks->bnqk", C_c, B_c)        # (B,nc,Q,Q)
    seg = Lc[:, :, :, None, :] - Lc[:, :, None, :, :]       # (B,nc,Q,Q,H) = L_t - L_i
    causal = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    # mask seg BEFORE exp: non-causal entries (i > t) have seg > 0 and can
    # overflow exp to inf, which the outer where hides in the forward pass
    # but turns into inf * 0 = NaN in the backward (the where-grad trap)
    decay = jnp.where(causal, jnp.exp(jnp.where(causal, seg, 0.0)), 0.0)
    attn = scores[..., None] * decay                        # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bnqkh,bnkhp->bnqhp", attn.astype(u.dtype), u)

    # chunk summary state: S_n = sum_i exp(L_Q - L_i) u_i (outer) B_i
    tail = jnp.exp(Lc[:, :, -1:, :] - Lc)                   # (B,nc,Q,H)
    Sn = jnp.einsum("bnqh,bnqhp,bnqs->bnhps",
                    tail.astype(u.dtype), u, B_c)           # (B,nc,H,P,S)
    chunk_decay = jnp.exp(Lc[:, :, -1, :]).astype(jnp.float32)  # (B,nc,H)

    def step(h, inp):
        sn, dk = inp                                        # (B,H,P,S), (B,H)
        h_new = h * dk[..., None, None] + sn.astype(jnp.float32)
        return h_new, h                                     # emit state *before* chunk

    h0 = jnp.zeros((Bb, H, P, S), jnp.float32)
    hT, h_prev = jax.lax.scan(step, h0,
                              (jnp.moveaxis(Sn, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                     # (B,nc,H,P,S)

    # inter-chunk: y_t += exp(L_t) C_t . h_{chunk_start}
    inter_w = jnp.exp(Lc).astype(u.dtype)                   # (B,nc,Q,H)
    y_inter = jnp.einsum("bnqs,bnhps,bnqh->bnqhp",
                         C_c, h_prev.astype(u.dtype), inter_w)
    y = (y_intra + y_inter).reshape(Bb, T, H, P) + D[:, None] * xh * dt[..., None]
    return y[:, :T0], hT


def mamba2_forward(params: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                   cache: Optional[dict] = None
                   ) -> Tuple[jnp.ndarray, Optional[dict]]:
    s, d_inner, nheads, conv_ch = _mamba_dims(cfg)
    P, S = s.head_dim, s.d_state
    z, xBC, dt = _mamba2_split(params, x, cfg)
    A = -jnp.exp(params["A_log"])                           # (H,) negative
    dt_sp = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])

    if cache is None or x.shape[1] > 1:
        conv_hist = cache["conv"] if cache is not None else None
        new_conv_hist = (jnp.concatenate([cache["conv"], xBC], axis=1)
                         [:, -(s.d_conv - 1):, :] if cache is not None else None)
        xBC = _causal_conv(xBC, params["conv_w"], params["conv_b"],
                           history=conv_hist)
        xi = xBC[..., :d_inner].reshape(*x.shape[:2], nheads, P)
        Bm = xBC[..., d_inner : d_inner + S]
        Cm = xBC[..., d_inner + S :]
        log_a = dt_sp * A                                   # (B,T,H)
        y, hT = _mamba2_core_chunked(xi, Bm, Cm, log_a, dt_sp, params["D"],
                                     s.chunk_size)
        new_cache = (None if cache is None
                     else {"conv": new_conv_hist, "state": hT})
    else:
        # single-token decode
        new_conv_hist = jnp.concatenate([cache["conv"], xBC], axis=1)[:, 1:, :]
        xBC = _causal_conv(xBC, params["conv_w"], params["conv_b"],
                           history=cache["conv"])
        xi = xBC[..., :d_inner].reshape(x.shape[0], 1, nheads, P)
        Bm = xBC[..., d_inner : d_inner + S]                # (B,1,S)
        Cm = xBC[..., d_inner + S :]
        a = jnp.exp(dt_sp * A)[:, 0]                        # (B,H)
        u = (xi * dt_sp[..., None])[:, 0]                   # (B,H,P)
        h = (cache["state"] * a[..., None, None]
             + jnp.einsum("bhp,bs->bhps", u.astype(jnp.float32),
                          Bm[:, 0].astype(jnp.float32)))
        y = (jnp.einsum("bhps,bs->bhp", h, Cm[:, 0].astype(jnp.float32))
             + params["D"][:, None] * xi[:, 0] * dt_sp[:, 0, :, None])
        y = y[:, None].astype(x.dtype)                      # (B,1,H,P)
        new_cache = {"conv": new_conv_hist, "state": h}

    y = y.reshape(*x.shape[:2], d_inner) * jax.nn.silu(z)
    y = rmsnorm(params["out_norm"], y, cfg.norm_eps)
    out = jnp.einsum("bti,id->btd", y, params["out_proj"])
    return out.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# RWKV6 (Finch)
# ---------------------------------------------------------------------------


def _rwkv_dims(cfg: ModelConfig):
    s: SSMConfig = cfg.ssm
    K = s.head_dim
    nheads = cfg.d_model // K
    return s, nheads, K


def init_rwkv6(rng, cfg: ModelConfig) -> dict:
    """RWKV6 time-mix: token-shift lerp, r/k/v/g projections, data-dependent
    per-channel decay w via a LoRA on the shifted input, bonus u."""
    s, H, K = _rwkv_dims(cfg)
    d = cfg.d_model
    lora = max(32, d // 16)
    ks = jax.random.split(rng, 8)
    return {
        "mix": 0.5 * ones((5, d), cfg.param_dtype),         # lerp for r,k,v,g,w
        "wr": fan_in_init(ks[0], (d, d), cfg.param_dtype),
        "wk": fan_in_init(ks[1], (d, d), cfg.param_dtype),
        "wv": fan_in_init(ks[2], (d, d), cfg.param_dtype),
        "wg": fan_in_init(ks[3], (d, d), cfg.param_dtype),
        "w_base": -6.0 * ones((d,), jnp.float32),           # decay bias
        "w_lora_a": fan_in_init(ks[4], (d, lora), cfg.param_dtype),
        "w_lora_b": zeros((lora, d), cfg.param_dtype),
        "u": zeros((H, K), jnp.float32),                    # bonus
        "out_norm": init_rmsnorm(d, cfg.param_dtype),
        "wo": fan_in_init(ks[5], (d, d), cfg.param_dtype),
    }


def init_rwkv6_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    s, H, K = _rwkv_dims(cfg)
    return {
        "tm_last": jnp.zeros((batch, 1, cfg.d_model), dtype),
        "cm_last": jnp.zeros((batch, 1, cfg.d_model), dtype),
        "state": jnp.zeros((batch, H, K, K), jnp.float32),
    }


def _token_shift(x: jnp.ndarray, last: Optional[jnp.ndarray]) -> jnp.ndarray:
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _wkv_chunked(r, k, v, log_w, u, chunk: int):
    """Chunked RWKV6 WKV.  r/k/v: (B,T,H,K), log_w: (B,T,H,K) negative,
    u: (H,K).  Returns y (B,T,H,K) and final state (B,H,K,K)."""
    Bb, T0, H, K = r.shape
    Q = min(chunk, T0)
    pad = (-T0) % Q
    if pad:
        pw = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, pw), jnp.pad(k, pw), jnp.pad(v, pw)
        log_w = jnp.pad(log_w, pw)     # log w = 0 -> decay 1, k=0 -> no-op
    T = T0 + pad
    nc = T // Q

    def sp(t):
        return t.reshape(Bb, nc, Q, H, K)

    r_c, k_c, v_c = sp(r), sp(k), sp(v)
    lw = sp(log_w).astype(jnp.float32)
    # L_t = sum_{j<=t} log w_j within chunk (w_t multiplies *previous* state)
    L = jnp.cumsum(lw, axis=2)
    # intra: y_t = sum_{i<t} (r_t * exp(L_{t-1}-L_i)) . k_i v_i + (r_t*u*k_t).v_t
    L_prev = L - lw                                         # L_{t-1} (exclusive)
    rw = r_c.astype(jnp.float32) * jnp.exp(L_prev)          # (B,n,Q,H,K)
    kw = k_c.astype(jnp.float32) * jnp.exp(-L)
    scores = jnp.einsum("bnqhk,bnihk->bnhqi", rw, kw)       # i<q strictly
    strict = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
    scores = jnp.where(strict[None, None, None], scores, 0.0)
    diag = jnp.einsum("bnqhk,hk,bnqhk->bnqh", r_c.astype(jnp.float32), u,
                      k_c.astype(jnp.float32))
    y_intra = (jnp.einsum("bnhqi,bnihk->bnqhk", scores, v_c.astype(jnp.float32))
               + diag[..., None] * v_c.astype(jnp.float32))

    # chunk summary: S_n = sum_i exp(L_Q - L_i) k_i (outer) v_i ; decay exp(L_Q)
    tail = jnp.exp(L[:, :, -1:, :, :] - L)                  # (B,n,Q,H,K)
    Sn = jnp.einsum("bnqhk,bnqhv->bnhkv", (k_c.astype(jnp.float32) * tail),
                    v_c.astype(jnp.float32))
    cdecay = jnp.exp(L[:, :, -1])                           # (B,n,H,K)

    def step(S, inp):
        sn, dk = inp
        return S * dk[..., None] + sn, S

    S0 = jnp.zeros((Bb, H, K, K), jnp.float32)
    ST, S_prev = jax.lax.scan(step, S0, (jnp.moveaxis(Sn, 1, 0),
                                         jnp.moveaxis(cdecay, 1, 0)))
    S_prev = jnp.moveaxis(S_prev, 0, 1)                     # (B,n,H,K,V)
    y_inter = jnp.einsum("bnqhk,bnhkv->bnqhv", rw, S_prev)
    y = (y_intra + y_inter).reshape(Bb, T, H, K)
    return y[:, :T0], ST


def rwkv6_forward(params: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                  cache: Optional[dict] = None
                  ) -> Tuple[jnp.ndarray, Optional[dict]]:
    s, H, K = _rwkv_dims(cfg)
    d = cfg.d_model
    last = cache["tm_last"] if cache is not None else None
    xs = _token_shift(x, last)
    mixed = [x + m * (xs - x) for m in params["mix"]]       # r,k,v,g,w inputs
    r = jnp.einsum("btd,de->bte", mixed[0], params["wr"]).reshape(*x.shape[:2], H, K)
    k = jnp.einsum("btd,de->bte", mixed[1], params["wk"]).reshape(*x.shape[:2], H, K)
    v = jnp.einsum("btd,de->bte", mixed[2], params["wv"]).reshape(*x.shape[:2], H, K)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", mixed[3], params["wg"]))
    w_dd = (params["w_base"]
            + jnp.einsum("btd,dl,le->bte", mixed[4], params["w_lora_a"],
                         params["w_lora_b"]).astype(jnp.float32))
    log_w = -jnp.exp(w_dd).reshape(*x.shape[:2], H, K)      # (B,T,H,K) < 0

    if cache is None or x.shape[1] > 1:
        # train / chunked prefill: the wkv recurrence runs on the
        # cfg.kernels backend (ref = _wkv_chunked below, pallas = the
        # chunked Pallas kernel with reference-VJP backward)
        y, ST = dispatch.backend_for(cfg).wkv(r, k, v, log_w, params["u"],
                                              chunk=s.chunk_size)
        new_cache = (None if cache is None else
                     {"tm_last": x[:, -1:], "cm_last": cache["cm_last"],
                      "state": ST})
    else:
        S = cache["state"]                                  # (B,H,K,V)
        r1 = r[:, 0].astype(jnp.float32)
        k1 = k[:, 0].astype(jnp.float32)
        v1 = v[:, 0].astype(jnp.float32)
        w1 = jnp.exp(log_w[:, 0])                           # (B,H,K)
        kv = jnp.einsum("bhk,bhv->bhkv", k1, v1)
        y = jnp.einsum("bhk,bhkv->bhv", r1, S + params["u"][None, :, :, None] * kv)
        S = S * w1[..., None] + kv
        y = y[:, None]
        new_cache = {"tm_last": x, "cm_last": cache["cm_last"], "state": S}

    y = y.reshape(*x.shape[:2], d).astype(x.dtype) * g.reshape(*x.shape[:2], d).astype(x.dtype)
    y = rmsnorm(params["out_norm"], y, cfg.norm_eps)
    out = jnp.einsum("btd,de->bte", y, params["wo"])
    return out.astype(x.dtype), new_cache


# --- RWKV channel mix (the FFN of an RWKV block) ---------------------------


def init_rwkv_cm(rng, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    ks = jax.random.split(rng, 3)
    return {
        "mix": 0.5 * ones((2, d), cfg.param_dtype),         # lerp for k, r
        "wk": fan_in_init(ks[0], (d, cfg.d_ff), cfg.param_dtype),
        "wv": fan_in_init(ks[1], (cfg.d_ff, d), cfg.param_dtype),
        "wr": fan_in_init(ks[2], (d, d), cfg.param_dtype),
    }


def rwkv_cm_forward(params: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                    last: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    xs = _token_shift(x, last)
    xk = x + params["mix"][0] * (xs - x)
    xr = x + params["mix"][1] * (xs - x)
    k = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", xk, params["wk"])))
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, params["wr"]))
    return (r * jnp.einsum("btf,fd->btd", k, params["wv"])).astype(x.dtype)
