"""Deliverable (f): per-assigned-architecture smoke tests — a REDUCED variant
of the same family (<=4 layers, d_model<=512, <=4 experts) runs one forward
and one fused Hetero-SplitEE train step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as configs_mod
from repro.config import (HeteroProfile, OptimizerConfig, SplitEEConfig,
                          TrainConfig)
from repro.core.spmd import (StepConfig, boundary_ids_for_batch,
                             make_serve_step, make_train_step)
from repro.models.backbone import backbone_forward, init_backbone, init_cache
from repro.optim import adam_init

ARCHS = configs_mod.all_arch_ids()


def _reduced_limits_ok(cfg):
    assert cfg.num_layers <= 4
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4


def _batch_for(cfg, B, T):
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    batch = {"tokens": jax.random.randint(ks[0], (B, T), 0, cfg.vocab_size),
             "labels": jax.random.randint(ks[1], (B, T), 0, cfg.vocab_size)}
    if cfg.arch_type == "audio":
        batch["enc"] = jnp.zeros((B, cfg.cross_source_len, 768), cfg.dtype)
    if cfg.arch_type == "vlm":
        from repro.models import frontend as fe
        P = 4
        batch["embeds"] = jnp.zeros((B, P, fe.SIGLIP_PATCH_DIM), cfg.dtype)
        batch["labels"] = jnp.concatenate(
            [jnp.zeros((B, P), jnp.int32), batch["labels"]], axis=1)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = configs_mod.get(arch).smoke()
    _reduced_limits_ok(cfg)
    params = init_backbone(jax.random.PRNGKey(0), cfg)
    B, T = 2, 16
    batch = _batch_for(cfg, B, T)
    out = backbone_forward(params, cfg, tokens=batch["tokens"],
                           embeds=batch.get("embeds"), enc=batch.get("enc"))
    T_out = batch["labels"].shape[1]
    assert out.logits.shape == (B, T_out, cfg.vocab_size)
    assert not bool(jnp.isnan(out.logits).any())
    for e in out.exit_logits:
        assert e.shape == (B, T_out, cfg.vocab_size)
        assert not bool(jnp.isnan(e).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = configs_mod.get(arch).smoke()
    prof = HeteroProfile(split_layers=(cfg.exit_layers[0],) * 2
                         + (cfg.exit_layers[-1],) * 2)
    sc = StepConfig(model=cfg, splitee=SplitEEConfig(profile=prof),
                    train=TrainConfig(optimizer=OptimizerConfig(
                        lr=1e-3, total_steps=10)))
    params = init_backbone(jax.random.PRNGKey(0), cfg)
    opt = adam_init(params, sc.train.optimizer)
    B, T = 4, 16
    batch = _batch_for(cfg, B, T)
    batch["split_ids"] = boundary_ids_for_batch(prof, cfg, B)
    step = jax.jit(make_train_step(sc))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["server_loss"]))
    for k, v in metrics.items():
        assert np.isfinite(float(v)), k
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ["glm4-9b", "zamba2-1.2b", "rwkv6-3b",
                                  "deepseek-v3-671b", "whisper-small"])
def test_smoke_decode_step(arch):
    cfg = configs_mod.get(arch).smoke()
    prof = HeteroProfile(split_layers=(cfg.exit_layers[0],) * 4)
    sc = StepConfig(model=cfg, splitee=SplitEEConfig(
        profile=prof, entropy_threshold=1.0), train=TrainConfig())
    params = init_backbone(jax.random.PRNGKey(0), cfg)
    B = 4
    cache = init_cache(cfg, B, 32, cfg.dtype)
    serve = jax.jit(make_serve_step(sc, boundary=0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0,
                              cfg.vocab_size)
    kw = {}
    if cfg.arch_type == "audio":
        kw["enc"] = jnp.zeros((B, cfg.cross_source_len, 768), cfg.dtype)
    out = serve(params, toks, cache, jnp.zeros((), jnp.int32), **kw)
    assert out["logits"].shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(out["logits"], np.float32)).all()


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned hyper-parameters."""
    expect = {
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
    }
    for arch, (L, d, H, kv, dff, V) in expect.items():
        cfg = configs_mod.get(arch).config()
        assert cfg.num_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == H, arch
        assert cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == dff, arch
        assert cfg.vocab_size == V, arch
        assert cfg.source, arch                 # citation present
    # family checks
    assert configs_mod.get("deepseek-v3-671b").config().moe.num_experts == 256
    assert configs_mod.get("deepseek-v3-671b").config().moe.top_k == 8
    assert configs_mod.get("qwen3-moe-235b-a22b").config().moe.num_experts == 128
    assert configs_mod.get("zamba2-1.2b").config().ssm.d_state == 64
    assert "shared_attn" in configs_mod.get("zamba2-1.2b").config().block_pattern
    assert configs_mod.get("rwkv6-3b").config().block_pattern[0] == "rwkv6"
    assert configs_mod.get("deepseek-v3-671b").config().mla is not None
