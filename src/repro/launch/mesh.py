"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (module import never touches jax
device state).  Target: TPU v5e, 16x16 = 256 chips per pod; the multi-pod
configuration stacks 2 pods (512 chips) behind a leading "pod" axis used for
data parallelism across the DCN/ICI boundary.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape: Tuple[int, ...] = (2, 2),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh over host CPU devices for tests/examples."""
    return jax.make_mesh(shape, axes)


def axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh) -> Tuple[str, ...]:
    """The mesh axes a global batch shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
