"""Shared training-step builders for every engine.

The paper-faithful per-client training loop lives in
``repro.api.reference_engine.ReferenceEngine`` as a pure
``TrainState -> TrainState`` executor behind the :class:`repro.api.TrainSession`
facade; this module keeps what all engines share:

  * :func:`make_client_step` / :func:`make_server_step` — pure functions of
    ``(pytrees, batch, lr)`` closed over the model/optimizer config only.
    The reference engine jits them one client at a time (the paper-faithful
    oracle); the fused and spmd engines compose the same functions into the
    cohort step (``core.spmd.make_cohort_train_step``) that runs vmapped
    over stacked client cohorts, so every engine runs numerically identical
    math in ``eq1`` grad mode.
  * :class:`RoundMetrics` — the per-round metric record.

Gradients never flow from server to client (``h_i`` enters the server step
as data), and every model is initialized from the same random seed via the
adapters in ``core/splitee.py`` (paper §III-B).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax

from repro.config import OptimizerConfig
from repro.core.losses import softmax_cross_entropy
from repro.optim import adam_update


@dataclass
class RoundMetrics:
    round: int
    client_loss: float
    server_loss: float


# ---------------------------------------------------------------------------
# Shared step-builders
# ---------------------------------------------------------------------------


def client_loss_fn(model) -> Callable:
    """The client-side training loss: the adapter's ``client_loss`` hook
    when it defines one (``(trainable, state, x, y) -> (loss, (h,
    new_state))`` — BackboneSplitModel adds its MoE load-balancing aux loss
    there, weighted per the config), else the protocol default: exit-head
    cross-entropy.  Evaluation never uses the hook — aux losses are a
    training regularizer only."""
    custom = getattr(model, "client_loss", None)
    if custom is not None:
        return custom

    def loss_fn(trainable, state, x, y):
        h, logits, new_state = model.client_forward(trainable, state, x,
                                                    train=True)
        return softmax_cross_entropy(logits, y), (h, new_state)

    return loss_fn


def server_loss_fn(model, li: int) -> Callable:
    """The server-side training loss: the adapter's ``server_loss`` hook
    (``(trainable, state, h, li, y) -> (loss, new_state)``, closed over
    ``li`` here) when defined, else final-head cross-entropy."""
    custom = getattr(model, "server_loss", None)
    if custom is not None:
        def loss_fn(trainable, state, h, y):
            return custom(trainable, state, h, li, y)
        return loss_fn

    def loss_fn(trainable, state, h, y):
        logits, new_state = model.server_forward(trainable, state, h, li,
                                                 train=True)
        return softmax_cross_entropy(logits, y), new_state

    return loss_fn


def make_client_step(model, opt_cfg: OptimizerConfig) -> Callable:
    """(trainable, state, opt, x, y, lr) ->
    (trainable, state, opt, h, loss) — Alg. 1/2 lines 6-11."""
    loss_fn = client_loss_fn(model)

    def step(trainable, state, opt, x, y, lr):
        (loss, (h, new_state)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(trainable, state, x, y)
        trainable, opt = adam_update(trainable, grads, opt, opt_cfg, lr)
        return trainable, new_state, opt, h, loss

    return step


def make_server_step(model, opt_cfg: OptimizerConfig, li: int) -> Callable:
    """(trainable, state, opt, h, y, lr) ->
    (trainable, state, opt, loss) — Alg. 1/2 lines 12-16; ``h`` enters as
    data, so no gradient ever flows back to the client."""
    loss_fn = server_loss_fn(model, li)

    def step(trainable, state, opt, h, y, lr):
        (loss, new_state), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(trainable, state, h, y)
        trainable, opt = adam_update(trainable, grads, opt, opt_cfg, lr)
        return trainable, new_state, opt, loss

    return step
