"""``ServeSession`` — the inference front door: Alg.-3 entropy-gated
serving with continuous batching, restored straight from ``TrainSession``
checkpoints.

Where :class:`~repro.api.session.TrainSession` owns the training half of the
paper, ``ServeSession`` owns the deployment half (Alg. 3 / Fig. 2): a fixed
pool of **decode slots** serves a stream of requests, each slot holding one
request's KV/state cache page and per-slot ``cache_len``.  Requests join a
free slot (prefill), decode one gated token per tick through a single
compiled step, and leave when their budget is spent — admission and eviction
never recompile the decode program.

The gate is the one graph :func:`repro.core.spmd.make_serve_step` builds —
entropy at the client-boundary exit head, ``exit iff H < tau`` (see
docs/DESIGN.md §1 for the paper's sign convention) — vmapped over slots so
every slot carries its own ``cache_len``.  Two exit policies:

  * ``"select"`` (default, paper Fig.-2 measurement mode): every tick
    computes both the exit and the full path and selects per token —
    bit-identical to a sequential ``make_serve_step`` run per request
    (tests/test_serve_session.py asserts exact parity, gate decisions
    included).
  * ``"sticky"`` (deployment mode): a request whose gate fires *adopts* the
    client path — from then on its tokens come from the client sub-network
    + exit head alone.  On ticks where every occupied slot has adopted, the
    session runs a client-only program (segments ``0..boundary``), so
    adopted slots genuinely stop consuming server-side layer work — the
    compute saving the adoption ratio trades against accuracy.  On mixed
    ticks (a fresh request admitted next to adopted slots) the full step
    runs with the per-slot sticky mask forcing adopted slots' gates open
    (``tau = +inf``), so they still take the exit-head token — which
    depends only on their client-layer caches, kept coherent by every
    policy path — and the server cache pages left stale by client-only
    ticks are never consulted for output.

Checkpoint restore reassembles one coherent full-network parameter tree
from the ``TrainState`` of a :class:`repro.core.backbone_splitee.
BackboneSplitModel` run: the serving client's segments + exit head on the
client side of the cut, its server's segments + LM head beyond it
(exactly the composed network that client's requests were trained to
traverse).  The manifest is validated the same way ``TrainSession.restore``
validates it (kind, format, adapter identity).

    session = ServeSession.restore("ckpt/run1/ckpt-00000100", model,
                                   tau=1.5, slots=8, max_len=128)
    session.submit(prompt_tokens, decode_tokens=16)
    results = session.run()          # list of ServeResult

Sharding rides the same recipe rules training uses:
``launch.shardings.serve_state_specs`` places the parameter tree and the
slot-paged cache over a mesh (params per ``ShardingRecipe``, slot dim over
the batch axes), and the jitted step preserves that placement.
"""
from __future__ import annotations

import functools
import json
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree
from repro.config import (HeteroProfile, ModelConfig, SplitEEConfig,
                          TrainConfig)
from repro.core.spmd import StepConfig, make_serve_step
from repro.kernels import dispatch
from repro.models import frontend as frontend_mod
from repro.models import heads as heads_mod
from repro.models.backbone import (backbone_forward, build_plan, init_cache,
                                   _run_forward)
from repro.models.common import embed


# ---------------------------------------------------------------------------
# boundary resolution — the single sorted source of truth
# ---------------------------------------------------------------------------


def resolve_serve_boundary(cfg: ModelConfig, boundary: int
                           ) -> Tuple[Tuple[int, ...], int, float]:
    """``(exits, cut, skip_frac)`` for gate boundary ``boundary``.

    One derivation feeds all three consumers — the gate head index
    (``backbone_forward`` emits ``exit_logits`` in sorted-exit order), the
    split profile, and the reported compute saving — so they can never
    disagree, whatever order ``cfg.exit_layers`` was written in."""
    exits = tuple(sorted(cfg.exit_layers))
    if not exits:
        raise ValueError(f"{cfg.name}: serving needs exit_layers (the gate "
                         f"sits at an exit head)")
    if not 0 <= boundary < len(exits):
        raise ValueError(f"boundary {boundary} out of range for "
                         f"{len(exits)} exit boundaries {exits}")
    cut = exits[boundary]
    skip_frac = 1.0 - cut / cfg.num_layers
    return exits, cut, skip_frac


def serve_step_config(cfg: ModelConfig, tau: float, boundary: int
                      ) -> Tuple[StepConfig, int, float]:
    """The ``StepConfig`` for :func:`make_serve_step` plus ``(cut,
    skip_frac)``, all derived through :func:`resolve_serve_boundary`."""
    exits, cut, skip_frac = resolve_serve_boundary(cfg, boundary)
    profile = HeteroProfile(split_layers=(cut,) * 4)
    sc = StepConfig(model=cfg,
                    splitee=SplitEEConfig(profile=profile,
                                          entropy_threshold=tau),
                    train=TrainConfig())
    return sc, cut, skip_frac


# ---------------------------------------------------------------------------
# checkpoint -> full serving parameter tree
# ---------------------------------------------------------------------------


def assemble_serve_params(model, state, boundary: int) -> dict:
    """One full-network parameter tree from a split ``TrainState``.

    ``model`` is a ``BackboneSplitModel``-shaped adapter (``cfg``, ``plan``,
    ``full_params``); the serving identity is the first client whose cut
    boundary equals ``boundary``: its embed/segments/exit head cover layers
    up to the cut, its server's ``seg{si}``/``head`` cover the rest — the
    exact composed network that client's requests traversed in training.
    Exit heads at other boundaries are taken from clients that trained them
    where present (falling back to the adapter's init values); they are
    computed by the forward pass but never consulted by the gate."""
    cfg = model.cfg
    exits = tuple(sorted(cfg.exit_layers))
    # a client at boundary b holds segments 0..b, so its boundary is
    # recoverable from the checkpoint state alone
    splits = tuple(len(c["trainable"]["segments"]) - 1 for c in state.clients)
    try:
        ci = splits.index(boundary)
    except ValueError:
        raise ValueError(
            f"no client in the checkpoint serves boundary {boundary} "
            f"(cut layer {exits[boundary]}); client boundaries: "
            f"{sorted(set(splits))}") from None
    client = state.clients[ci]["trainable"]
    si_srv = ci if len(state.servers) > 1 else 0
    server = state.servers[si_srv]["trainable"]

    n_seg = len(model.plan)
    segments = [client["segments"][si] for si in range(boundary + 1)]
    for si in range(boundary + 1, n_seg):
        segments.append(server[f"seg{si}"])

    exit_heads = []
    for b in range(len(exits)):
        if b == boundary:
            exit_heads.append(client["out"])
            continue
        owner = next((i for i, sb in enumerate(splits) if sb == b), None)
        exit_heads.append(state.clients[owner]["trainable"]["out"]
                          if owner is not None
                          else model.full_params["exit_heads"][b])

    params = {"embed": client["embed"], "segments": segments,
              "exit_heads": exit_heads, "head": server["head"]}
    for key in ("shared_attn", "frontend"):
        if key in client:
            params[key] = client[key]
        elif key in model.full_params:
            params[key] = model.full_params[key]
    return params


# ---------------------------------------------------------------------------
# request / result records
# ---------------------------------------------------------------------------


@dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray                 # (P,) int32
    decode_tokens: int


@dataclass
class ServeResult:
    """One request's served stream.  ``tokens[0]`` is the prefill token
    (full-path, ungated — there is no boundary decision before the first
    decode tick); ``tokens[1 + i]`` is the output of gated decode tick
    ``i`` with decision ``exited[i]`` and gate entropy ``entropy[i]``."""
    rid: int
    prompt: np.ndarray
    tokens: List[int] = field(default_factory=list)
    exited: List[bool] = field(default_factory=list)
    entropy: List[float] = field(default_factory=list)

    @property
    def adoption_ratio(self) -> float:
        return float(np.mean(self.exited)) if self.exited else 0.0


@dataclass
class ServeStats:
    requests: int = 0
    decode_ticks: int = 0
    tokens: int = 0                    # gated decode tokens served
    exited: int = 0
    client_only_ticks: int = 0         # sticky ticks that skipped the server
    wall_s: float = 0.0

    @property
    def adoption_ratio(self) -> float:
        return self.exited / max(1, self.tokens)


# ---------------------------------------------------------------------------
# the session
# ---------------------------------------------------------------------------


class ServeSession:
    """Continuous-batching entropy-gated decode over a fixed slot pool."""

    def __init__(self, cfg: ModelConfig, params: dict, *, tau: float,
                 boundary: int = 0, slots: int = 8, max_len: int = 128,
                 exit_policy: str = "select", mesh=None, recipe=None,
                 kernels: Optional[str] = None):
        if exit_policy not in ("select", "sticky"):
            raise ValueError(f"unknown exit_policy {exit_policy!r}; "
                             f"expected 'select' or 'sticky'")
        if kernels is not None:
            # kernels is layout/backend, not math: overriding it at serve
            # time is always sound (equivalence-gated in tier-1)
            dispatch.resolve_kernels(kernels)     # validate loudly
            cfg = cfg.with_(kernels=kernels)
        self.cfg = cfg
        self.tau = float(tau)
        self.boundary = boundary
        self.slots = slots
        self.max_len = max_len
        self.exit_policy = exit_policy
        self.sc, self.cut, self.skip_frac = serve_step_config(
            cfg, tau, boundary)
        self.params = params
        self.mesh = mesh

        if mesh is not None:
            from repro.launch.shardings import (resolve_recipe,
                                                serve_state_specs, to_named)
            cache0 = init_cache(cfg, slots, max_len, cfg.dtype)
            specs = serve_state_specs(resolve_recipe(recipe), mesh,
                                      params, cache0, cfg)
            self.params = jax.device_put(params,
                                         to_named(specs["params"], mesh))
            self._pool = jax.device_put(cache0,
                                        to_named(specs["cache"], mesh))
        else:
            self._pool = init_cache(cfg, slots, max_len, cfg.dtype)

        # stacked-run cache leaves carry a leading layer dim, so the slot
        # (batch) axis is 1 there and 0 elsewhere — one axes tree drives
        # vmap, the join scatter, and the in-lane expand/strip
        axes = cache_slot_axes(cfg)
        out_axes = {"tokens": 0, "exited": 0, "entropy": 0, "cache": axes}
        step = make_serve_step(self.sc, boundary=boundary)
        self._slot_step = jax.jit(jax.vmap(
            functools.partial(_one_slot, step, cfg, axes),
            in_axes=(None, 0, axes, 0, None, 0), out_axes=out_axes))
        self._client_step = jax.jit(jax.vmap(
            functools.partial(_one_slot_client_only, cfg, boundary, axes),
            in_axes=(None, 0, axes, 0, None, 0), out_axes=out_axes))
        self._prefill = jax.jit(functools.partial(_prefill, cfg, max_len))
        self._join = jax.jit(functools.partial(_join_slot, axes))

        # host-side scheduler state
        self._queue: deque = deque()
        self._slot_req: List[Optional[ServeRequest]] = [None] * slots
        self._slot_res: List[Optional[ServeResult]] = [None] * slots
        self._slot_left = np.zeros(slots, np.int64)
        self._slot_sticky = np.zeros(slots, bool)
        self._active = np.zeros(slots, bool)
        self._toks = jnp.zeros((slots,), jnp.int32)
        self._lens = jnp.zeros((slots,), jnp.int32)
        self._next_rid = 0
        self._done: List[ServeResult] = []
        self.stats = ServeStats()

    # -------------------------------------------------------------- restore
    @classmethod
    def restore(cls, path: str, model, *, tau: Optional[float] = None,
                boundary: Optional[int] = None, slots: int = 8,
                max_len: int = 128, exit_policy: str = "select",
                mesh=None, recipe=None,
                kernels: Optional[str] = None) -> "ServeSession":
        """Build a serving session straight from a ``TrainSession``
        checkpoint (the ``path + '.npz'/'.json'`` pair ``TrainSession.save``
        writes).  ``model`` must be the adapter the run trained —
        the manifest's kind, format, and adapter identity are validated
        before any tensor is read, exactly like ``TrainSession.restore``.
        ``tau`` defaults to the checkpoint's ``entropy_threshold``;
        ``boundary`` defaults to the shallowest trained cut."""
        from repro.api.session import CHECKPOINT_FORMAT, _model_name
        from repro.api.state import init_train_state
        from repro.config import OptimizerConfig

        with open(path + ".json") as f:
            meta = json.load(f)["metadata"]
        if meta.get("kind") != "train_session":
            raise ValueError(f"{path} is not a TrainSession checkpoint")
        if meta.get("format") != CHECKPOINT_FORMAT:
            raise ValueError(
                f"{path} has checkpoint format {meta.get('format')!r}; this "
                f"version reads format {CHECKPOINT_FORMAT}")
        saved_model = meta.get("model")
        if saved_model is not None and saved_model != _model_name(model):
            raise ValueError(
                f"checkpoint was saved with model {saved_model!r} but "
                f"restore got {_model_name(model)!r}; the state cannot be "
                f"served as a different architecture")

        sp = meta["splitee"]
        splitee_cfg = SplitEEConfig(
            profile=HeteroProfile(tuple(sp["split_layers"])),
            strategy=sp["strategy"],
            server_lr_divisor=sp["server_lr_divisor"],
            aggregate_every=sp["aggregate_every"],
            entropy_threshold=sp["entropy_threshold"])
        opt = dict(meta["optimizer"])
        opt["state_dtype"] = jnp.dtype(opt["state_dtype"])
        state = init_train_state(model, splitee_cfg, OptimizerConfig(**opt))
        state = load_pytree(path, state)

        if boundary is None:
            boundary = min(model._boundary_of(li)
                           for li in splitee_cfg.profile.split_layers)
        params = assemble_serve_params(model, state, boundary)
        return cls(model.cfg, params,
                   tau=(sp["entropy_threshold"] if tau is None else tau),
                   boundary=boundary, slots=slots, max_len=max_len,
                   exit_policy=exit_policy, mesh=mesh, recipe=recipe,
                   kernels=kernels)

    # ------------------------------------------------------------ admission
    def submit(self, prompt: Sequence[int], decode_tokens: int = 16) -> int:
        """Enqueue one request; returns its id.  The request joins a slot at
        the next :meth:`step` with one free."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if decode_tokens < 1:
            raise ValueError(f"decode_tokens must be >= 1, got "
                             f"{decode_tokens}")
        if len(prompt) + 1 + decode_tokens > self.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + decode ({decode_tokens}) tokens "
                f"exceed the slot page (max_len={self.max_len})")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(ServeRequest(rid, prompt, decode_tokens))
        return rid

    def _admit(self) -> None:
        for s in range(self.slots):
            if self._active[s] or not self._queue:
                continue
            req = self._queue.popleft()
            cache1, tok0, P = self._prefill(self.params,
                                            jnp.asarray(req.prompt))
            self._pool = self._join(self._pool, cache1, s)
            self._toks = self._toks.at[s].set(tok0)
            self._lens = self._lens.at[s].set(P)
            self._slot_req[s] = req
            self._slot_res[s] = ServeResult(req.rid, req.prompt,
                                            tokens=[int(tok0)])
            self._slot_left[s] = req.decode_tokens
            self._slot_sticky[s] = False
            self._active[s] = True

    # --------------------------------------------------------------- ticks
    def step(self) -> bool:
        """One scheduler tick: admit queued requests into free slots, decode
        one gated token on every occupied slot, evict finished requests.
        Returns False when queue and slots are both empty."""
        t0 = time.perf_counter()
        self._admit()
        occupied = np.nonzero(self._active)[0]
        if not len(occupied):
            return False

        client_only = (self.exit_policy == "sticky"
                       and bool(self._slot_sticky[occupied].all()))
        fn = self._client_step if client_only else self._slot_step
        # under the sticky policy adopted slots carry their mask into the
        # step: the full path forces their gate open so the exit head is
        # selected even when client-only ticks left server pages stale
        sticky = jnp.asarray(self._slot_sticky
                             if self.exit_policy == "sticky"
                             else np.zeros(self.slots, bool))
        out = fn(self.params, self._toks, self._pool, self._lens,
                 jnp.float32(self.tau), sticky)
        self._pool = out["cache"]
        next_toks = out["tokens"]
        exited = np.asarray(out["exited"])
        entropy = np.asarray(out["entropy"], np.float32)
        toks_host = np.asarray(next_toks)

        adv = jnp.asarray(self._active, jnp.int32)
        self._lens = self._lens + adv
        self._toks = jnp.where(jnp.asarray(self._active), next_toks,
                               self._toks)

        for s in occupied:
            res = self._slot_res[s]
            res.tokens.append(int(toks_host[s]))
            res.exited.append(bool(exited[s]))
            res.entropy.append(float(entropy[s]))
            self._slot_sticky[s] |= bool(exited[s])
            self._slot_left[s] -= 1
            self.stats.tokens += 1
            self.stats.exited += int(exited[s])
            if self._slot_left[s] <= 0:
                self._done.append(res)
                self.stats.requests += 1
                self._slot_req[s] = self._slot_res[s] = None
                self._active[s] = False
        self.stats.decode_ticks += 1
        self.stats.client_only_ticks += int(client_only)
        self.stats.wall_s += time.perf_counter() - t0
        return bool(self._queue) or bool(self._active.any())

    def run(self) -> List[ServeResult]:
        """Drain the queue; returns all finished results in completion
        order (also kept on ``self.results``)."""
        while self.step():
            pass
        return self.results

    @property
    def results(self) -> List[ServeResult]:
        return list(self._done)


# ---------------------------------------------------------------------------
# per-slot step bodies (vmapped over the slot pool)
# ---------------------------------------------------------------------------


def cache_slot_axes(cfg: ModelConfig) -> list:
    """Per-run slot-axis tree matching the ``init_cache`` structure: the
    slot (batch) dim sits behind the layer-stack dim for stacked runs."""
    return [[1 if run.length > 1 else 0 for run in seg]
            for seg in build_plan(cfg)]


def _expand_slot(axes, cache):
    """Re-insert a size-1 slot dim (stripped by vmap) at each run's slot
    axis, giving the B=1 cache ``backbone_forward`` expects."""
    return [[jax.tree.map(functools.partial(jnp.expand_dims, axis=ax), runc)
             for ax, runc in zip(seg_ax, seg_c)]
            for seg_ax, seg_c in zip(axes, cache)]


def _strip_slot(axes, cache):
    """Inverse of :func:`_expand_slot`."""
    return [[jax.tree.map(lambda a, ax=ax: jnp.squeeze(a, axis=ax), runc)
             for ax, runc in zip(seg_ax, seg_c)]
            for seg_ax, seg_c in zip(axes, cache)]


def _one_slot(step, cfg: ModelConfig, axes, params, tok, cache, cache_len,
              tau, sticky):
    """One decode slot through the full gated serve step.  ``tok`` is the
    slot's last token (scalar), ``cache`` its page with the slot dim already
    stripped by vmap, ``cache_len`` its fill scalar.

    ``sticky`` (scalar bool, always False under the ``"select"`` policy)
    forces the gate open (``tau = +inf``) for a slot that already adopted
    the client path: its token then comes from the exit head, which reads
    only the client-layer caches — coherent across both policy paths — so
    server cache pages left stale by earlier client-only ticks are never
    consulted for output (they are rewritten here, but an adopted slot
    never selects the full path again)."""
    cache1 = _expand_slot(axes, cache)
    kw = {}
    if cfg.cross_attention:
        kw["enc"] = jnp.zeros((1, cfg.cross_source_len,
                               frontend_mod.WHISPER_FRAME_DIM), cfg.dtype)
    tau_eff = jnp.where(sticky, jnp.float32(jnp.inf), tau)
    out = step(params, tok[None, None], cache1, cache_len, tau=tau_eff, **kw)
    return {"tokens": jnp.argmax(out["logits"][0, 0], -1).astype(jnp.int32),
            "exited": out["exited"][0, 0],
            "entropy": out["entropy"][0, 0],
            "cache": _strip_slot(axes, out["cache"])}


def _one_slot_client_only(cfg: ModelConfig, boundary: int, axes, params,
                          tok, cache, cache_len, tau, sticky):
    """The sticky-adoption fast path: segments ``0..boundary`` + exit head
    only — server-side layers do zero work.  ``ServeSession`` runs this
    only on ticks where every occupied slot has adopted.  Server-segment
    cache pages go stale, which is sound because an adopted slot's output
    never depends on them again: on later mixed ticks (new admissions) the
    scheduler passes the slot's ``sticky`` flag to :func:`_one_slot`, which
    forces the gate open so the exit head — fed only by the client-layer
    caches this path keeps coherent — is always selected."""
    plan = build_plan(cfg)
    cache1 = _expand_slot(axes, cache)
    x = embed(params["embed"], tok[None, None]).astype(cfg.dtype)
    positions = cache_len + jnp.arange(1, dtype=jnp.int32)
    enc = None
    if cfg.cross_attention and "frontend" in params:
        raw = jnp.zeros((1, cfg.cross_source_len,
                         frontend_mod.WHISPER_FRAME_DIM), cfg.dtype)
        enc = frontend_mod.project(params["frontend"], raw).astype(cfg.dtype)
    shared_p = params.get("shared_attn")
    new_cache = [list(seg) for seg in cache1]
    for si in range(boundary + 1):
        for ri, run in enumerate(plan[si]):
            x, run_c, _ = _run_forward(run, params["segments"][si][ri],
                                       shared_p, x, positions, cfg,
                                       cache1[si][ri], cache_len, enc, False)
            new_cache[si][ri] = run_c
    e_logits = heads_mod.exit_head(params["exit_heads"][boundary], x, cfg)
    H, gate = dispatch.backend_for(cfg).entropy_gate(e_logits, tau)
    # every occupied slot here has adopted; report the token as exited
    # (it comes from the exit head) regardless of the instantaneous H
    return {"tokens": jnp.argmax(e_logits[0, 0], -1).astype(jnp.int32),
            "exited": sticky | gate[0, 0],
            "entropy": H[0, 0],
            "cache": _strip_slot(axes, new_cache)}


def _prefill(cfg: ModelConfig, max_len: int, params, prompt):
    """Prefill one request at its exact prompt length: ``(cache page
    (leaves (1, W, ...)), first token, prompt length)``.  Compiles once per
    distinct prompt length; the decode step itself never recompiles."""
    kw = {}
    if cfg.cross_attention:
        kw["enc"] = jnp.zeros((1, cfg.cross_source_len,
                               frontend_mod.WHISPER_FRAME_DIM), cfg.dtype)
    # a fresh page per request: the previous occupant's tokens never leak
    cache = init_cache(cfg, 1, max_len, cfg.dtype)
    out = backbone_forward(params, cfg, tokens=prompt[None], cache=cache,
                           cache_len=jnp.zeros((), jnp.int32), **kw)
    tok0 = jnp.argmax(out.logits[0, -1], -1).astype(jnp.int32)
    return out.cache, tok0, jnp.asarray(prompt.shape[0], jnp.int32)


def _join_slot(axes, pool, page, slot):
    """Scatter one prefilled B=1 page into the slot pool at ``slot`` along
    each run's slot axis (traced index — joining never recompiles)."""
    def upd(ax):
        return lambda p, a: jax.lax.dynamic_update_index_in_dim(
            p, jnp.squeeze(a, axis=ax), slot, ax)
    return [[jax.tree.map(upd(ax), pool_r, page_r)
             for ax, pool_r, page_r in zip(seg_ax, seg_p, seg_g)]
            for seg_ax, seg_p, seg_g in zip(axes, pool, page)]


# ---------------------------------------------------------------------------
# sequential reference (the parity oracle)
# ---------------------------------------------------------------------------


def sequential_reference(cfg: ModelConfig, params: dict,
                         prompt: Sequence[int], decode_tokens: int, *,
                         tau: float, boundary: int = 0, max_len: int = 128
                         ) -> ServeResult:
    """Serve ONE request alone: B=1 prefill + a raw ``make_serve_step``
    decode loop — the paper-faithful sequential path the continuous-batching
    engine must reproduce token-for-token, gate decisions included
    (tests/test_serve_session.py and serve_bench gate on it)."""
    sc, _, _ = serve_step_config(cfg, tau, boundary)
    step = jax.jit(make_serve_step(sc, boundary=boundary))
    kw = {}
    if cfg.cross_attention:
        kw["enc"] = jnp.zeros((1, cfg.cross_source_len,
                               frontend_mod.WHISPER_FRAME_DIM), cfg.dtype)
    prompt = np.asarray(prompt, np.int32).reshape(-1)
    cache = init_cache(cfg, 1, max_len, cfg.dtype)
    out = backbone_forward(params, cfg, tokens=jnp.asarray(prompt)[None],
                           cache=cache, cache_len=jnp.zeros((), jnp.int32),
                           **kw)
    tok = jnp.argmax(out.logits[0, -1], -1).astype(jnp.int32)
    cache = out.cache
    res = ServeResult(rid=-1, prompt=prompt, tokens=[int(tok)])
    P = len(prompt)
    for i in range(decode_tokens):
        o = step(params, tok[None, None], cache,
                 jnp.asarray(P + i, jnp.int32), tau=jnp.float32(tau), **kw)
        cache = o["cache"]
        tok = jnp.argmax(o["logits"][0, 0], -1).astype(jnp.int32)
        res.tokens.append(int(tok))
        res.exited.append(bool(o["exited"][0, 0]))
        res.entropy.append(float(o["entropy"][0, 0]))
    return res


def sequential_sticky_reference(cfg: ModelConfig, params: dict,
                                prompt: Sequence[int], decode_tokens: int,
                                *, tau: float, boundary: int = 0,
                                max_len: int = 128) -> ServeResult:
    """Serve ONE request alone under the sticky policy: after the first
    gate fire every later tick runs with the gate forced open
    (``tau = +inf``), so all remaining tokens come from the exit head —
    exactly the adoption rule ``ServeSession`` applies per slot.  Unlike
    the batched engine this loop computes the full path every tick, so
    every cache page stays coherent; matching it token-for-token is the
    proof that the engine's stale server pages never leak into a sticky
    slot's stream (tests/test_serve_session.py gates on it across
    mid-stream admissions)."""
    sc, _, _ = serve_step_config(cfg, tau, boundary)
    step = jax.jit(make_serve_step(sc, boundary=boundary))
    kw = {}
    if cfg.cross_attention:
        kw["enc"] = jnp.zeros((1, cfg.cross_source_len,
                               frontend_mod.WHISPER_FRAME_DIM), cfg.dtype)
    prompt = np.asarray(prompt, np.int32).reshape(-1)
    cache = init_cache(cfg, 1, max_len, cfg.dtype)
    out = backbone_forward(params, cfg, tokens=jnp.asarray(prompt)[None],
                           cache=cache, cache_len=jnp.zeros((), jnp.int32),
                           **kw)
    tok = jnp.argmax(out.logits[0, -1], -1).astype(jnp.int32)
    cache = out.cache
    res = ServeResult(rid=-1, prompt=prompt, tokens=[int(tok)])
    P = len(prompt)
    sticky = False
    for i in range(decode_tokens):
        tau_i = jnp.float32(jnp.inf) if sticky else jnp.float32(tau)
        o = step(params, tok[None, None], cache,
                 jnp.asarray(P + i, jnp.int32), tau=tau_i, **kw)
        cache = o["cache"]
        tok = jnp.argmax(o["logits"][0, 0], -1).astype(jnp.int32)
        res.tokens.append(int(tok))
        res.exited.append(bool(o["exited"][0, 0]))
        res.entropy.append(float(o["entropy"][0, 0]))
        sticky = sticky or res.exited[-1]
    return res
