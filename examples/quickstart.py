"""Quickstart: Hetero-SplitEE in ~60 seconds on CPU.

Three heterogeneous clients (cut layers 1/2/3 of a 4-layer net) train one
shared model collaboratively with the Averaging strategy (paper Alg. 2),
then serve with the entropy-gated early exit (Alg. 3).

Training uses ``FusedHeteroTrainer``, the scan+vmap engine that runs the
whole training run as one compiled program (see docs/ENGINES.md); swap in
``repro.core.strategies.HeteroTrainer`` for the paper-faithful round-by-round
reference — both produce the same numbers.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.config import HeteroProfile, OptimizerConfig, SplitEEConfig
from repro.core.fused import FusedHeteroTrainer
from repro.core.splitee import MLPSplitModel
from repro.data.pipeline import ClientPartitioner


def main():
    rng = np.random.default_rng(0)
    n, d, classes = 3000, 32, 5
    centers = rng.normal(size=(classes, d)) * 1.5
    y = rng.integers(0, classes, n).astype(np.int32)
    x = (centers[y] + rng.normal(size=(n, d))).astype(np.float32)
    train, test = (x[:2400], y[:2400]), (x[2400:], y[2400:])

    model = MLPSplitModel(in_dim=d, hidden=64, num_classes=classes,
                          num_layers=4, seed=0)
    profile = HeteroProfile(split_layers=(1, 2, 3))   # heterogeneous cuts
    clients = ClientPartitioner(3, seed=0).split(*train)

    trainer = FusedHeteroTrainer(
        model,
        SplitEEConfig(profile=profile, strategy="averaging"),
        OptimizerConfig(lr=3e-3, total_steps=60),
        clients, batch_size=64)
    trainer.run(rounds=40, local_epochs=1, log_every=10)

    ev = trainer.evaluate(*test)
    print("\nper-client accuracy (cut layers 1/2/3):")
    print("  client-side exits:", [f"{a:.3f}" for a in ev["client_acc"]])
    print("  server-side      :", [f"{a:.3f}" for a in ev["server_acc"]])

    print("\nadaptive inference (exit iff entropy < tau):")
    for tau in (0.1, 0.5, 1.0):
        ad = trainer.evaluate_adaptive(*test, tau=tau)
        print(f"  tau={tau:.1f}  acc={np.mean(ad['acc']):.3f}  "
              f"client-ratio={np.mean(ad['client_ratio']):.2f}")


if __name__ == "__main__":
    main()
