# Launch layer: production mesh, sharding recipes, dry-run, train & serve drivers.
