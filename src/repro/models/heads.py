"""Output heads.

``lm_head``   — final norm + unembedding (the *server output layer* of the
                paper, transplanted to token models).
``exit_head`` — the paper's lightweight *client output layer* `f_i^(o)`: for
                token models a norm + linear classifier (EE-LLM style); for
                image models average-pool + fc (paper Table I).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.common import fan_in_init, init_rmsnorm, rmsnorm


def init_lm_head(rng, cfg: ModelConfig) -> dict:
    return {
        "norm": init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "w": fan_in_init(rng, (cfg.d_model, cfg.vocab_size), cfg.param_dtype),
    }


def lm_head(params: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    return jnp.einsum("btd,dv->btv", h, params["w"])


init_exit_head = init_lm_head
exit_head = lm_head
