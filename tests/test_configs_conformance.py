"""Registry-wide conformance of ``src/repro/configs/``: every architecture
module must expose the ``config()`` / ``smoke()`` / ``profile()`` triple the
``--arch`` CLI resolves through, with a ``HeteroProfile`` whose split layers
are legal cut points of the config it describes."""
import importlib
import pkgutil

import pytest

import repro.configs as configs_pkg
from repro import configs as configs_mod
from repro.config import HeteroProfile, ModelConfig

ALL_MODULES = sorted(
    m.name for m in pkgutil.iter_modules(configs_pkg.__path__)
    if not m.name.startswith("_"))


def test_registry_covers_all_arch_modules():
    # every assigned arch id resolves to a module in the package
    for arch in configs_mod.all_arch_ids():
        mod = configs_mod.get(arch)
        assert mod.__name__.rsplit(".", 1)[-1] in ALL_MODULES
    # and the package holds exactly the assigned archs + the paper's ResNet
    assert set(ALL_MODULES) == set(configs_mod.ARCH_IDS) | {"resnet18_cifar"}


@pytest.mark.parametrize("name", ALL_MODULES)
def test_module_exposes_triple(name):
    mod = importlib.import_module(f"repro.configs.{name}")
    for fn in ("config", "smoke", "profile"):
        assert callable(getattr(mod, fn, None)), f"{name} lacks {fn}()"


@pytest.mark.parametrize("name", ALL_MODULES)
def test_profile_split_layers_are_legal_cuts(name):
    mod = importlib.import_module(f"repro.configs.{name}")
    cfg = mod.config()
    prof = mod.profile()
    assert isinstance(prof, HeteroProfile)
    assert prof.num_groups >= 1
    for li in prof.split_layers:
        assert 1 <= li < cfg.num_layers, (name, li)
    if isinstance(cfg, ModelConfig):
        # token backbones cut at exit-head boundaries (BackboneSplitModel)
        assert set(prof.split_layers) <= set(cfg.exit_layers), name


@pytest.mark.parametrize("name", ALL_MODULES)
def test_smoke_is_reduced_and_splittable(name):
    mod = importlib.import_module(f"repro.configs.{name}")
    cfg = mod.smoke()
    if not isinstance(cfg, ModelConfig):       # the ResNet paper model
        return
    assert cfg.num_layers <= 4
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    # exit heads exist so the smoke config trains through the adapter
    assert cfg.exit_layers, name
    for li in cfg.exit_layers:
        assert 1 <= li < cfg.num_layers, (name, li)
