"""Shared machinery for the paper-table benchmarks.

The paper's experiments are 600-epoch ResNet-18 runs on CIFAR/STL; this
offline CPU container reproduces the *comparisons* (strategy orderings,
difficulty trends, threshold trade-off) at reduced scale: width-0.25
ResNet-18, synthetic class-conditional datasets (see data/synthetic.py), 12
clients, tens of rounds.  Absolute accuracies are NOT comparable to the
paper; orderings and gaps are — see docs/EXPERIMENTS.md §Paper-validation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from repro.api import TrainSession
from repro.config import HeteroProfile, OptimizerConfig, SplitEEConfig
from repro.configs import resnet18_cifar
from repro.core.splitee import ResNetSplitModel
from repro.data.pipeline import ClientPartitioner
from repro.data.synthetic import SyntheticImageDataset

# dataset stand-ins.  Difficulty comes primarily from class count at fixed
# per-client sample budgets (the CIFAR-10 vs CIFAR-100 relationship the
# paper's claims rely on); noise tuned so a width-0.125 ResNet reaches ~90%
# (10-class) vs ~15-20%% (100-class) within the CPU step budget.  synstl adds
# noise and cuts data 4x (STL's 5k train set).
DATASETS = {
    "syn10": dict(num_classes=10, noise=2.0),      # CIFAR-10 stand-in
    "syn100": dict(num_classes=100, noise=1.0),    # CIFAR-100 stand-in
    "synstl": dict(num_classes=10, noise=3.0),     # STL-10 stand-in
}


# 16x16 inputs (vs the paper's 32x32): 4x cheaper convolutions on the
# single-core CPU host; the Table-I layer structure is unchanged.
IMAGE_SIZE = 16


def make_dataset(name: str, train_size: int, test_size: int, seed: int = 0
                 ) -> SyntheticImageDataset:
    kw = DATASETS[name]
    if name == "synstl":
        train_size = max(256, train_size // 4)      # STL has 10x less train
    return SyntheticImageDataset(train_size=train_size, test_size=test_size,
                                 image_size=IMAGE_SIZE, seed=seed, **kw)


def run_strategy(dataset: SyntheticImageDataset, strategy: str,
                 splits: Sequence[int], *, rounds: int, local_epochs: int = 1,
                 batch_size: int = 64, width_mult: float = 0.125,
                 lr: float = 3e-3, seed: int = 0, engine: str = "auto"
                 ) -> Dict:
    """Train one (strategy, split-profile) cell and evaluate per split depth.

    ``engine`` is a registered engine name or ``"auto"`` (the default):
    the fused scan+vmap engine where it applies, the paper-faithful
    reference engine for ordered strategies.  Sequential/centralized
    cells degrade an explicit ``engine="fused"`` to ``"auto"`` (fused
    cannot run ordered strategies), so one engine choice can drive a
    whole table."""
    if strategy in ("sequential", "centralized") and engine == "fused":
        engine = "auto"
    cfg = resnet18_cifar.config("cifar10", width_mult=width_mult)
    cfg = dataclasses.replace(cfg, num_classes=dataset.num_classes)
    model = ResNetSplitModel(cfg, seed=seed)
    x, y = dataset.train

    if strategy == "centralized":
        # all data on one client per distinct split depth (paper upper bound)
        results = {"client_acc": [], "server_acc": [],
                   "split_layers": sorted(set(splits))}
        for li in sorted(set(splits)):
            steps = rounds * max(1, len(splits))    # same global step budget
            sess = TrainSession.from_config(
                model, SplitEEConfig(profile=HeteroProfile((li,)),
                                     strategy="sequential"),
                OptimizerConfig(lr=lr, total_steps=steps),
                [(x, y)], batch_size=batch_size, engine=engine,
                augment=SyntheticImageDataset.augment, seed=seed)
            sess.train(steps, local_epochs)
            ev = sess.evaluate(*dataset.test, batch_size=256)
            results["client_acc"].append(ev["client_acc"][0])
            results["server_acc"].append(ev["server_acc"][0])
        return results

    parts = ClientPartitioner(len(splits), seed=seed).split(x, y)
    sess = TrainSession.from_config(
        model, SplitEEConfig(profile=HeteroProfile(tuple(splits)),
                             strategy=strategy),
        OptimizerConfig(lr=lr, total_steps=rounds),
        parts, batch_size=batch_size, engine=engine,
        augment=SyntheticImageDataset.augment, seed=seed)
    sess.train(rounds, local_epochs)
    ev = sess.evaluate(*dataset.test, batch_size=256)
    ev["session"] = ev["trainer"] = sess    # "trainer" kept for old readers
    return ev


def mean_by_depth(ev: Dict, splits: Sequence[int]) -> Dict[int, Dict[str, float]]:
    """Average client/server accuracy over clients sharing a split depth
    (how Tables III/IV report columns)."""
    out: Dict[int, Dict[str, List[float]]] = {}
    for i, li in enumerate(splits):
        d = out.setdefault(li, {"client": [], "server": []})
        d["client"].append(ev["client_acc"][i])
        d["server"].append(ev["server_acc"][i])
    return {li: {k: float(np.mean(v)) for k, v in d.items()}
            for li, d in out.items()}
