"""Pre-``import jax`` helper: force fake host-CPU devices from an argv flag.

jax locks the device count at first initialization, so CLIs that offer a
``--host-devices N``-style flag must translate it into
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* anything
imports jax.  This module is import-safe for that purpose: it touches only
``os``/``sys``.  Both ``--flag N`` and ``--flag=N`` forms are accepted (a
flag with no value is left for argparse to reject).
"""
from __future__ import annotations

import os
import sys


def force_host_devices(flag: str, argv=None) -> int:
    """Scan ``argv`` (default ``sys.argv``) for ``flag``; when it requests
    more than one device, append the XLA force-host-device-count flag to
    ``XLA_FLAGS``.  Returns the requested count (0 if absent/unparsable)."""
    argv = sys.argv if argv is None else argv
    n = 0
    for i, a in enumerate(argv):
        try:
            if a == flag and i + 1 < len(argv):
                n = int(argv[i + 1])
                break
            if a.startswith(flag + "="):
                n = int(a.split("=", 1)[1])
                break
        except ValueError:
            return 0                    # malformed; argparse will complain
    if n > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n}")
    return n
