"""Multi-host scale-out smoke: a real 2-process CPU ``jax.distributed``
training (2 fake devices per process, 4 global) through the
``repro.launch.train`` CLI must land on exactly the same losses and
accuracies as the identical single-process 4-device run — the spmd
engine's process-local staging (``make_array_from_process_local_data``)
and replicating carry fetch are pure layout.  Also pins the
``launch.distributed`` option resolution (argv flags, ``REPRO_*`` env
fallbacks, XLA flag injection) and the coordinator-only checkpoint
gating.  CI runs this module as the ``distributed-smoke`` job.
"""
import os
import re
import socket
import subprocess
import sys

import pytest

from repro.launch.distributed import (ASYNC_COLLECTIVE_XLA_FLAGS,
                                      resolve_options, setup_from_argv)

TOL = 1e-4
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = pytest.mark.distributed


# ---------------------------------------------------------------------------
# option resolution (no jax, no subprocess)
# ---------------------------------------------------------------------------


def test_resolve_options_from_argv():
    o = resolve_options(["prog", "--distributed",
                         "--coordinator", "10.0.0.1:1234",
                         "--num-processes=4", "--process-id", "2"])
    assert o.enabled and o.coordinator == "10.0.0.1:1234"
    assert o.num_processes == 4 and o.process_id == 2
    assert not resolve_options(["prog", "--rounds", "5"]).enabled
    # --coordinator alone implies a distributed run
    assert resolve_options(["prog", "--coordinator=h:1"]).enabled


def test_resolve_options_env_fallbacks(monkeypatch):
    monkeypatch.setenv("REPRO_DISTRIBUTED", "1")
    monkeypatch.setenv("REPRO_COORDINATOR", "h:99")
    monkeypatch.setenv("REPRO_NUM_PROCESSES", "2")
    monkeypatch.setenv("REPRO_PROCESS_ID", "1")
    o = resolve_options(["prog"])
    assert o.enabled and o.coordinator == "h:99"
    assert o.num_processes == 2 and o.process_id == 1
    monkeypatch.setenv("REPRO_DISTRIBUTED", "0")
    monkeypatch.delenv("REPRO_COORDINATOR")
    assert not resolve_options(["prog"]).enabled


def test_resolve_options_malformed_env_raises(monkeypatch):
    """A malformed REPRO_NUM_PROCESSES/REPRO_PROCESS_ID must fail loudly:
    argparse never sees env vars, and silently dropping the value sends
    jax.distributed into cluster auto-detection (hangs or fails with no
    hint of the real cause).  Malformed *argv* values still defer to
    argparse, which owns the canonical error message."""
    monkeypatch.setenv("REPRO_NUM_PROCESSES", "two")
    with pytest.raises(ValueError, match="REPRO_NUM_PROCESSES"):
        resolve_options(["prog"])
    monkeypatch.setenv("REPRO_NUM_PROCESSES", "2")
    monkeypatch.setenv("REPRO_PROCESS_ID", "zero")
    with pytest.raises(ValueError, match="REPRO_PROCESS_ID"):
        resolve_options(["prog"])
    monkeypatch.delenv("REPRO_PROCESS_ID")
    # argv-sourced garbage is argparse's to report, not ours
    o = resolve_options(["prog", "--num-processes", "nope"])
    assert o.num_processes is None
    # a malformed argv value must not mask a good env fallback's sibling
    assert resolve_options(["prog", "--process-id=bad"]).num_processes == 2


def test_setup_appends_xla_flags_once(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "--xla_force_host_platform_device_count=2")
    assert setup_from_argv(["prog"]).enabled is False
    assert "latency_hiding" not in os.environ["XLA_FLAGS"]   # non-distributed
    setup_from_argv(["prog", "--distributed"])
    flags = os.environ["XLA_FLAGS"]
    for f in ASYNC_COLLECTIVE_XLA_FLAGS:
        assert f in flags
    assert "--xla_force_host_platform_device_count=2" in flags
    setup_from_argv(["prog", "--distributed"])               # idempotent
    assert os.environ["XLA_FLAGS"] == flags


# ---------------------------------------------------------------------------
# the 2-process parity run
# ---------------------------------------------------------------------------

ARGS = ["--model", "mlp", "--clients", "4", "--rounds", "4", "--batch", "32",
        "--train-size", "256", "--test-size", "64", "--engine", "spmd",
        "--log-every", "0", "--save-every", "2"]


def _launch(extra):
    env = {"PYTHONPATH": "src", "PATH": os.environ.get("PATH", ""),
           "HOME": os.environ.get("HOME", "/tmp"), "JAX_PLATFORMS": "cpu"}
    return subprocess.Popen(
        [sys.executable, "-m", "repro.launch.train", *ARGS, *extra],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env, cwd=ROOT)


def _finish(proc, timeout=600):
    out, _ = proc.communicate(timeout=timeout)
    assert proc.returncode == 0, out[-4000:]
    return out


def _parse(out):
    """(client_loss, server_loss, [(client_acc, server_acc, adaptive), ...])."""
    m = re.search(r"client_loss ([\d.]+)\s+server_loss ([\d.]+)", out)
    assert m, out[-2000:]
    accs = re.findall(r"client_acc ([\d.]+)\s+server_acc ([\d.]+)\s+"
                      r"adaptive_acc ([\d.]+)", out)
    assert len(accs) == 4, out[-2000:]
    return (float(m.group(1)), float(m.group(2)),
            [tuple(map(float, a)) for a in accs])


@pytest.fixture(scope="module")
def runs(tmp_path_factory):
    d = tmp_path_factory.mktemp("dist")
    port = socket.socket()
    port.bind(("", 0))
    coord = f"127.0.0.1:{port.getsockname()[1]}"
    port.close()

    single = _launch(["--host-devices", "4",
                      "--checkpoint-dir", str(d / "single")])
    single_out = _finish(single)

    common = ["--host-devices", "2", "--distributed", "--coordinator", coord,
              "--num-processes", "2"]
    p0 = _launch([*common, "--process-id", "0",
                  "--checkpoint-dir", str(d / "rank0")])
    p1 = _launch([*common, "--process-id", "1",
                  "--checkpoint-dir", str(d / "rank1")])
    out0, out1 = _finish(p0), _finish(p1)
    return single_out, out0, out1, d


def test_two_process_run_spans_global_devices(runs):
    _, out0, out1, _ = runs
    assert "devices=4 (2 processes, rank 0)  engine=spmd" in out0
    assert "devices=4 (2 processes, rank 1)  engine=spmd" in out1


def test_two_process_parity_with_single_process(runs):
    """Acceptance: the 2-process distributed run reproduces the
    single-process 4-device losses and per-client accuracies."""
    single_out, out0, _, _ = runs
    closs_s, sloss_s, accs_s = _parse(single_out)
    closs_d, sloss_d, accs_d = _parse(out0)
    assert abs(closs_s - closs_d) <= TOL
    assert abs(sloss_s - sloss_d) <= TOL
    for a, b in zip(accs_s, accs_d):
        assert a == b, (accs_s, accs_d)


def test_ranks_agree_with_each_other(runs):
    _, out0, out1, _ = runs
    assert _parse(out0) == _parse(out1)


def test_only_the_coordinator_writes_checkpoints(runs):
    _, _, _, d = runs
    rank0 = sorted(os.listdir(d / "rank0"))
    assert any(f.startswith("ckpt-") for f in rank0)
    assert "driver.json" in rank0
    assert not (d / "rank1").exists() or not os.listdir(d / "rank1")
