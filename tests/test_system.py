"""End-to-end behaviour of the full system: the fused SPMD engine trains a
hetero-split transformer on structured synthetic LM data, early exits become
useful, and the adaptive gate trades accuracy for client-side exits."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import (HeteroProfile, ModelConfig, OptimizerConfig,
                          SplitEEConfig, TrainConfig)
from repro.core.losses import softmax_entropy
from repro.core.spmd import (StepConfig, boundary_ids_for_batch,
                             make_serve_step, make_train_step)
from repro.data.synthetic import SyntheticLMDataset
from repro.models.backbone import backbone_forward, init_backbone, init_cache
from repro.optim import adam_init


def test_end_to_end_hetero_lm_training():
    cfg = ModelConfig(name="e2e", arch_type="dense", num_layers=4, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                      exit_layers=(1, 2), dtype=jnp.float32,
                      param_dtype=jnp.float32)
    prof = HeteroProfile((1, 1, 2, 2))
    sc = StepConfig(model=cfg, splitee=SplitEEConfig(profile=prof),
                    train=TrainConfig(optimizer=OptimizerConfig(
                        lr=3e-3, total_steps=150)))
    params = init_backbone(jax.random.PRNGKey(0), cfg)
    opt = adam_init(params, sc.train.optimizer)
    step = jax.jit(make_train_step(sc))

    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=32,
                            structure=1.0, seed=0)
    B = 8
    sids = boundary_ids_for_batch(prof, cfg, B)
    first, tail = None, []
    for toks, labels in ds.batches(B, 120):
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels),
                 "split_ids": sids}
        params, opt, m = step(params, opt, batch)
        if first is None:
            first = float(m["server_loss"])
        tail.append(float(m["server_loss"]))
    last = float(np.mean(tail[-10:]))
    assert last < first * 0.75, (first, last)

    # exit heads after 1-2 layers cannot solve the in-context affine task
    # (that's the point of hierarchical depth); require sane, non-diverging
    # losses near/below uniform rather than task-level learning
    assert float(m["client_loss/b0"]) < np.log(cfg.vocab_size) * 1.2
    assert float(m["client_loss/b1"]) < np.log(cfg.vocab_size) * 1.2

    # adaptive decode: on structured data some tokens exit early at a
    # moderate threshold, none at tau=0, all at tau=ln(V)
    toks, _ = next(ds.batches(B, 1))
    cache = init_cache(cfg, B, 40, jnp.float32)
    pre = backbone_forward(params, cfg, tokens=jnp.asarray(toks), cache=cache,
                           cache_len=jnp.zeros((), jnp.int32))
    nxt = jnp.argmax(pre.logits[:, -1:], -1)
    ratios = {}
    for tau in (0.0, 1.5, np.log(cfg.vocab_size) + 1):
        sc_t = dataclasses.replace(
            sc, splitee=dataclasses.replace(sc.splitee,
                                            entropy_threshold=float(tau)))
        serve = jax.jit(make_serve_step(sc_t, boundary=0))
        out = serve(params, nxt, pre.cache, jnp.asarray(32, jnp.int32))
        ratios[tau] = float(np.asarray(out["exited"]).mean())
    taus = sorted(ratios)
    assert ratios[taus[0]] == 0.0
    assert ratios[taus[-1]] == 1.0
    assert ratios[taus[0]] <= ratios[taus[1]] <= ratios[taus[-1]]
