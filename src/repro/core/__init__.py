# Hetero-SplitEE core: the paper's contribution as composable JAX modules.
#   splitee.py      — split specs, per-client model partitioning (the
#                     repro.api.protocol.SplitModel adapters)
#   backbone_splitee.py — the production configs/ backbones behind the
#                     same SplitModel protocol (cuts at exit_layers)
#   losses.py       — CE / entropy / confidence
#   aggregation.py  — Eq. (1) cross-layer aggregation
#   strategies.py   — shared client/server step builders
#   spmd.py         — fused SPMD production train step (masked exits +
#                     routing) and the TrainState-boundary cohort step
#                     shared by the fused/spmd engines
#   inference.py    — Alg. 3 entropy-gated adaptive inference
#
# Training engines and the TrainSession facade live in repro.api
# (docs/API.md).
