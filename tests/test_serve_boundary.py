"""Regression: the ``--boundary`` serve path derives the gate head, the
split profile, and the reported cut from ONE sorted source.

The seed ``launch/serve.py`` hardcoded the profile to
``exit_layers[0]`` while the gate indexed ``exit_logits[boundary]``
(sorted order) and the printed cut used ``sorted(exit_layers)[boundary]``
— three different layers for unsorted ``exit_layers`` or ``--boundary >
0``.  These tests pin the single-source derivation
(``repro.api.serve_session.resolve_serve_boundary`` /
``serve_step_config``) on a config whose ``exit_layers`` are deliberately
written out of order.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs as configs_mod
from repro.api.serve_session import (resolve_serve_boundary,
                                     serve_step_config)
from repro.core.losses import softmax_entropy
from repro.core.spmd import make_serve_step
from repro.models.backbone import backbone_forward, init_backbone


@pytest.fixture(scope="module")
def unsorted_cfg():
    """A smoke config whose exit_layers are written in REVERSED order —
    the case the seed serve script silently mis-handled."""
    cfg = configs_mod.get("glm4-9b").smoke()
    exits = tuple(sorted(cfg.exit_layers))
    assert len(exits) >= 2
    return cfg.with_(exit_layers=tuple(reversed(exits)))


@pytest.mark.parametrize("boundary", [0, 1])
def test_gate_head_profile_and_report_agree(unsorted_cfg, boundary):
    """gate head == profile cut == reported cut, for every boundary, on an
    unsorted-exit config."""
    cfg = unsorted_cfg
    exits, cut, skip_frac = resolve_serve_boundary(cfg, boundary)
    assert exits == tuple(sorted(cfg.exit_layers))
    assert cut == exits[boundary]                       # reported cut
    sc, cut2, skip2 = serve_step_config(cfg, tau=2.0, boundary=boundary)
    assert cut2 == cut and skip2 == skip_frac
    # the profile every consumer receives is built from the same cut
    assert set(sc.splitee.profile.split_layers) == {cut}
    assert skip_frac == pytest.approx(1.0 - cut / cfg.num_layers)


@pytest.mark.parametrize("boundary", [0, 1])
def test_gate_entropy_comes_from_the_sorted_head(unsorted_cfg, boundary):
    """The serve step's gate entropy equals the entropy of
    ``exit_logits[boundary]`` in backbone emission (= sorted) order — the
    head after the reported cut layer, not after ``exit_layers[boundary]``
    as written in the config."""
    cfg = unsorted_cfg
    sc, cut, _ = serve_step_config(cfg, tau=2.0, boundary=boundary)
    params = init_backbone(jax.random.PRNGKey(0), cfg)
    step = make_serve_step(sc, boundary=boundary)
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 5)),
        jnp.int32)
    out = backbone_forward(params, cfg, tokens=tokens)
    got = step(params, tokens, None, None)
    np.testing.assert_allclose(
        np.asarray(got["entropy"]),
        np.asarray(softmax_entropy(out.exit_logits[boundary])), atol=1e-5)
    # heads at different boundaries genuinely disagree, so the assertion
    # above discriminates
    other = softmax_entropy(out.exit_logits[1 - boundary])
    assert not np.allclose(np.asarray(got["entropy"]), np.asarray(other),
                           atol=1e-5)


def test_bad_boundary_rejected(unsorted_cfg):
    with pytest.raises(ValueError, match="out of range"):
        resolve_serve_boundary(unsorted_cfg, 2)
    with pytest.raises(ValueError, match="out of range"):
        resolve_serve_boundary(unsorted_cfg, -1)


def test_no_exit_layers_rejected(unsorted_cfg):
    with pytest.raises(ValueError, match="exit_layers"):
        resolve_serve_boundary(unsorted_cfg.with_(exit_layers=()), 0)


def test_serve_cli_reports_consistent_cut(unsorted_cfg, capsys):
    """launch/serve.py main() prints the same cut the gate uses, via the
    shared helper (no separate derivation to drift)."""
    import sys
    from unittest import mock
    from repro.launch import serve as serve_cli

    argv = ["serve", "--arch", "glm4-9b", "--requests", "2", "--slots", "2",
            "--prompt-len", "4", "--decode-tokens", "2", "--boundary", "1"]
    with mock.patch.object(sys, "argv", argv):
        serve_cli.main()
    out = capsys.readouterr().out
    exits = sorted(configs_mod.get("glm4-9b").smoke().exit_layers)
    assert f"(cut layer {exits[1]}/" in out
