"""Split-model adapters: partition a layered network into a client-side net
(layers 1..l_i + client output layer) and a server-side net (layers l_i+1..L
+ server head), with all models initialized from the same random seed (paper
§III-B: "Initialize all networks from the same random seed").

Adapters implement the ``repro.api.protocol.SplitModel`` protocol consumed
by every registered training engine (enforced by the conformance test in
tests/test_session.py):

    make_client(l_i)  -> client pytree  {"trainable": {...}, "state": {...}}
    make_server(l_i)  -> server pytree  {"trainable": {layerK.., head}, "state"}
    client_forward(client, x, train)  -> (h, client_logits, new_state)
    server_forward(server, h, l_i, train) -> (server_logits, new_state)

``trainable`` holds everything the optimizer updates; ``state`` carries
non-differentiated statistics (BatchNorm running stats).  Server trainables
are keyed ``layer{l}``/``head`` so Eq. (1) aggregation matches layers by name.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.config import HeteroProfile
from repro.models import resnet as rn
from repro.models.common import fan_in_init, zeros


# ---------------------------------------------------------------------------
# Cohort stacking — shared by the fused/spmd engines (repro.api)
# ---------------------------------------------------------------------------


def stack_pytrees(trees: Sequence[Any]) -> Any:
    """Stack same-structure pytrees along a new leading "lane" axis.  Clients
    that share a split layer have identical tree structure, so a cohort of k
    clients becomes one pytree with [k, ...] leaves, ready for ``jax.vmap``."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def unstack_pytrees(stacked: Any, n: int) -> list:
    """Inverse of :func:`stack_pytrees`: split the leading lane axis back into
    ``n`` per-client pytrees (device-resident slices, no host copy)."""
    return [jax.tree.map(lambda x: x[i], stacked) for i in range(n)]


class _StackMixin:
    """Adapter-level cohort helpers: every split model can stack a cohort of
    same-shaped per-client pytrees for vmap and unstack them afterwards."""

    def stack_clients(self, trees: Sequence[Any]) -> Any:
        return stack_pytrees(trees)

    def unstack(self, stacked: Any, n: int) -> list:
        return unstack_pytrees(stacked, n)


# ---------------------------------------------------------------------------
# ResNet adapter (the paper's experimental model)
# ---------------------------------------------------------------------------


@dataclass
class ResNetSplitModel(_StackMixin):
    cfg: rn.ResNetConfig
    seed: int = 0

    def __post_init__(self):
        rng = jax.random.PRNGKey(self.seed)
        self.full_params, self.full_state = rn.init_resnet(rng, self.cfg)

    @property
    def num_layers(self) -> int:
        return self.cfg.num_layers

    def make_client(self, li: int) -> Dict[str, Any]:
        params = {f"layer{k}": self.full_params[f"layer{k}"]
                  for k in range(1, li + 1)}
        state = {f"layer{k}": self.full_state[f"layer{k}"]
                 for k in range(1, li + 1)}
        # client output layer: same seed for every client with the same l_i
        head = rn.init_client_head(jax.random.PRNGKey(self.seed + 1000 + li),
                                   self.cfg, li)
        return {"trainable": {"layers": params, "out": head}, "state": state}

    def make_server(self, li: int) -> Dict[str, Any]:
        params = {f"layer{k}": self.full_params[f"layer{k}"]
                  for k in range(li + 1, self.num_layers + 1)}
        params["head"] = self.full_params["head"]
        state = {f"layer{k}": self.full_state[f"layer{k}"]
                 for k in range(li + 1, self.num_layers + 1)}
        return {"trainable": params, "state": state}

    def client_forward(self, trainable, state, x, train: bool
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, Any]:
        h, new_state = rn.resnet_features(trainable["layers"], state, x,
                                          self.cfg, end_layer=len(trainable["layers"]),
                                          train=train)
        logits = rn.client_head_forward(trainable["out"], h)
        return h, logits, new_state

    def server_forward(self, trainable, state, h, li: int, train: bool
                       ) -> Tuple[jnp.ndarray, Any]:
        feats, new_state = rn.resnet_features(trainable, state, h, self.cfg,
                                              start_layer=li, train=train)
        logits = rn.head_forward(trainable["head"], feats)
        return logits, new_state


# ---------------------------------------------------------------------------
# Tiny MLP adapter (fast property tests / CI)
# ---------------------------------------------------------------------------


@dataclass
class MLPSplitModel(_StackMixin):
    """L-layer MLP on flat inputs; layer l is keyed ``layer{l}`` so the same
    strategy/aggregation machinery applies.  Used by tests and quick demos."""

    in_dim: int
    hidden: int
    num_classes: int
    num_layers: int = 6
    seed: int = 0
    dtype: Any = jnp.float32

    def __post_init__(self):
        rng = jax.random.PRNGKey(self.seed)
        ks = jax.random.split(rng, self.num_layers + 1)
        self.full_params = {}
        d_in = self.in_dim
        for l in range(1, self.num_layers + 1):
            self.full_params[f"layer{l}"] = {
                "w": fan_in_init(ks[l - 1], (d_in, self.hidden), self.dtype),
                "b": zeros((self.hidden,), self.dtype)}
            d_in = self.hidden
        self.full_params["head"] = {
            "w": fan_in_init(ks[-1], (self.hidden, self.num_classes), self.dtype),
            "b": zeros((self.num_classes,), self.dtype)}

    def make_client(self, li: int) -> Dict[str, Any]:
        layers = {f"layer{k}": self.full_params[f"layer{k}"]
                  for k in range(1, li + 1)}
        hrng = jax.random.PRNGKey(self.seed + 1000 + li)
        out = {"w": fan_in_init(hrng, (self.hidden, self.num_classes), self.dtype),
               "b": zeros((self.num_classes,), self.dtype)}
        return {"trainable": {"layers": layers, "out": out}, "state": {}}

    def make_server(self, li: int) -> Dict[str, Any]:
        params = {f"layer{k}": self.full_params[f"layer{k}"]
                  for k in range(li + 1, self.num_layers + 1)}
        params["head"] = self.full_params["head"]
        return {"trainable": params, "state": {}}

    def _apply_layers(self, layers: Dict[str, dict], h, keys):
        for k in keys:
            p = layers[k]
            h = jax.nn.relu(h @ p["w"] + p["b"])
        return h

    def client_forward(self, trainable, state, x, train: bool):
        h = x.reshape(x.shape[0], -1)
        keys = sorted(trainable["layers"], key=lambda s: int(s[5:]))
        h = self._apply_layers(trainable["layers"], h, keys)
        logits = h @ trainable["out"]["w"] + trainable["out"]["b"]
        return h, logits, state

    def server_forward(self, trainable, state, h, li: int, train: bool):
        keys = [f"layer{k}" for k in range(li + 1, self.num_layers + 1)]
        h = self._apply_layers(trainable, h, keys)
        logits = h @ trainable["head"]["w"] + trainable["head"]["b"]
        return logits, state
