"""Feed-forward blocks: SwiGLU (gated) and plain GeLU MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.common import activation, fan_in_init, zeros


def init_mlp(rng, cfg: ModelConfig, d_ff: int = 0) -> dict:
    d = cfg.d_model
    dff = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    p = {
        "w_gate": fan_in_init(ks[0], (d, dff), cfg.param_dtype),
        "w_up": fan_in_init(ks[1], (d, dff), cfg.param_dtype),
        "w_down": fan_in_init(ks[2], (dff, d), cfg.param_dtype),
    }
    if cfg.use_mlp_bias:
        p["b_up"] = zeros((dff,), cfg.param_dtype)
        p["b_down"] = zeros((d,), cfg.param_dtype)
    return p


def mlp_forward(params: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    act = activation(cfg.act)
    gate = jnp.einsum("btd,df->btf", x, params["w_gate"])
    up = jnp.einsum("btd,df->btf", x, params["w_up"])
    if "b_up" in params:
        up = up + params["b_up"]
    h = act(gate) * up
    out = jnp.einsum("btf,fd->btd", h, params["w_down"])
    if "b_down" in params:
        out = out + params["b_down"]
    return out.astype(x.dtype)
