"""Losses and confidence measures for Hetero-SplitEE."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean CE.  logits (..., V), labels (...) int; ``mask`` (...) selects the
    contributing elements (mean is over masked elements)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = logz - gold
    if mask is None:
        return jnp.mean(ce)
    m = mask.astype(jnp.float32)
    return jnp.sum(ce * m) / jnp.maximum(jnp.sum(m), 1.0)


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray,
             mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    hit = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    if mask is None:
        return jnp.mean(hit)
    m = mask.astype(jnp.float32)
    return jnp.sum(hit * m) / jnp.maximum(jnp.sum(m), 1.0)


def softmax_entropy(logits: jnp.ndarray) -> jnp.ndarray:
    """Paper Alg. 3: H = -sum_j p_j log p_j, computed stably in fp32.
    Returns shape logits.shape[:-1]."""
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.sum(jnp.exp(logp) * logp, axis=-1)
