"""The paper's own model: Table-I ResNet splits + end-to-end ResNet
Hetero-SplitEE training on the synthetic CIFAR stand-in."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import HeteroProfile, OptimizerConfig, SplitEEConfig
from repro.configs import resnet18_cifar
from repro.core.splitee import ResNetSplitModel
from repro.api import TrainSession
from repro.data.pipeline import ClientPartitioner
from repro.data.synthetic import SyntheticImageDataset
from repro.models.resnet import (ResNetConfig, init_client_head, init_resnet,
                                 resnet_features, resnet_forward)


def test_table1_structure():
    cfg = resnet18_cifar.config("cifar10")
    assert cfg.stem_stride == 1                # no downsample stem on CIFAR
    assert cfg.channels() == (64, 64, 64, 128, 256, 512)
    assert cfg.strides() == (1, 1, 1, 2, 2, 2)
    stl = resnet18_cifar.config("stl10")
    assert stl.stem_stride == 2
    c100 = resnet18_cifar.config("cifar100")
    assert c100.num_classes == 100
    prof = resnet18_cifar.profile()
    assert prof.split_layers == (3,) * 4 + (4,) * 4 + (5,) * 4


def test_resnet_forward_and_split():
    cfg = ResNetConfig(num_classes=10, width_mult=0.25)
    params, state = init_resnet(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    logits, ns = resnet_forward(params, state, x, cfg, train=True)
    assert logits.shape == (2, 10)
    assert not bool(jnp.isnan(logits).any())
    # bn state updated in train mode
    moved = any(not np.allclose(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(ns)))
    assert moved
    # split at 3 == full when composed
    h, _ = resnet_features(params, state, x, cfg, end_layer=3)
    full_feats, _ = resnet_features(params, state, x, cfg)
    comp, _ = resnet_features(params, state, h, cfg, start_layer=3)
    np.testing.assert_allclose(np.asarray(comp), np.asarray(full_feats),
                               atol=1e-5)


@pytest.mark.slow
def test_resnet_hetero_training_learns():
    ds = SyntheticImageDataset(num_classes=10, train_size=1536, test_size=512,
                               image_size=16, noise=2.0, seed=0)
    cfg = ResNetConfig(num_classes=10, width_mult=0.125, image_size=16)
    model = ResNetSplitModel(cfg, seed=0)
    prof = HeteroProfile((3, 4, 5))
    parts = ClientPartitioner(3, seed=0).split(*ds.train)
    tr = TrainSession.from_config(
        model, SplitEEConfig(profile=prof, strategy="averaging"),
        OptimizerConfig(lr=2e-3, total_steps=60), parts, batch_size=64,
        engine="reference")
    tr.train(rounds=40, local_epochs=2)
    ev = tr.evaluate(*ds.test, batch_size=256)
    # well above the 10% chance level on both sides of the split
    assert min(ev["client_acc"]) > 0.25, ev
    assert min(ev["server_acc"]) > 0.25, ev
