"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import entropy_exit, flash_attention, rwkv_wkv
from repro.kernels.ref import (entropy_exit_ref, flash_attention_ref,
                               rwkv_wkv_ref)

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("B,H,Hkv,T,S,D", [
    (2, 4, 2, 64, 64, 32),
    (1, 4, 1, 96, 96, 16),          # MQA, non-pow2 seq
    (2, 2, 2, 33, 33, 64),          # padding path
    (1, 8, 4, 128, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, Hkv, T, S, D, dtype):
    q = jnp.array(RNG.normal(size=(B, H, T, D)), dtype)
    k = jnp.array(RNG.normal(size=(B, Hkv, S, D)), dtype)
    v = jnp.array(RNG.normal(size=(B, Hkv, S, D)), dtype)
    out = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [8, 48])
def test_flash_attention_sliding_window(window):
    q = jnp.array(RNG.normal(size=(1, 2, 64, 32)), jnp.float32)
    k = jnp.array(RNG.normal(size=(1, 2, 64, 32)), jnp.float32)
    v = jnp.array(RNG.normal(size=(1, 2, 64, 32)), jnp.float32)
    out = flash_attention(q, k, v, window=window, block_q=16, block_k=16,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("B,V", [(8, 1000), (5, 4097), (16, 128),
                                 (3, 50000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_entropy_exit_sweep(B, V, dtype):
    x = jnp.array(RNG.normal(size=(B, V)) * 3, dtype)
    tau = 1.5
    H, ex = entropy_exit(x, tau, interpret=True)
    Hr, exr = entropy_exit_ref(x, tau)
    np.testing.assert_allclose(np.asarray(H), np.asarray(Hr), atol=1e-2,
                               rtol=1e-3)
    # decisions may differ only where H is within tol of tau
    diff = np.asarray(ex) != np.asarray(exr.astype(bool))
    assert np.all(np.abs(np.asarray(Hr)[diff] - tau) < 1e-2)


@pytest.mark.parametrize("B,T,H,K,chunk", [
    (2, 32, 2, 8, 8),
    (1, 50, 3, 16, 16),             # padding path
    (2, 64, 4, 32, 32),
])
def test_rwkv_wkv_sweep(B, T, H, K, chunk):
    r = jnp.array(RNG.normal(size=(B, T, H, K)), jnp.float32)
    k = jnp.array(RNG.normal(size=(B, T, H, K)), jnp.float32)
    v = jnp.array(RNG.normal(size=(B, T, H, K)), jnp.float32)
    lw = -jnp.array(RNG.uniform(0.05, 1.0, size=(B, T, H, K)), jnp.float32)
    u = jnp.array(RNG.normal(size=(H, K)), jnp.float32)
    y = rwkv_wkv(r, k, v, lw, u, chunk=chunk, interpret=True)

    def flat(x):
        return jnp.moveaxis(x, 2, 1).reshape(B * H, T, K)

    yr = rwkv_wkv_ref(flat(r), flat(k), flat(v), flat(lw),
                      jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, K))
    yr = jnp.moveaxis(yr.reshape(B, H, T, K), 1, 2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-4,
                               rtol=1e-3)


def test_rwkv_wkv_bf16_inputs():
    B, T, H, K = 1, 32, 2, 16
    r = jnp.array(RNG.normal(size=(B, T, H, K)), jnp.bfloat16)
    k = jnp.array(RNG.normal(size=(B, T, H, K)), jnp.bfloat16)
    v = jnp.array(RNG.normal(size=(B, T, H, K)), jnp.bfloat16)
    lw = -jnp.array(RNG.uniform(0.1, 1.0, size=(B, T, H, K)), jnp.float32)
    u = jnp.array(RNG.normal(size=(H, K)), jnp.float32)
    y = rwkv_wkv(r, k, v, lw, u, chunk=16, interpret=True)
    assert y.shape == (B, T, H, K)
    assert np.isfinite(np.asarray(y, np.float32)).all()


@pytest.mark.parametrize("B,H,Hkv,Tq,Tk,D,causal", [
    (1, 2, 1, 1, 33, 32, False),    # non-causal single-query decode
    (2, 4, 2, 7, 40, 16, False),    # non-causal ragged prefill
    (1, 2, 2, 40, 24, 16, False),   # Tq > Tk
    (2, 2, 1, 5, 64, 32, True),     # causal ragged (decode with history)
])
def test_flash_attention_ragged(B, H, Hkv, Tq, Tk, D, causal):
    """Regression: ops.flash_attention used to assert ``causal`` whenever it
    padded keys; the kernel now masks ``kpos >= Tk`` itself, so non-causal
    and ragged (Tq != Tk) shapes must match the oracle too."""
    q = jnp.array(RNG.normal(size=(B, H, Tq, D)), jnp.float32)
    k = jnp.array(RNG.normal(size=(B, Hkv, Tk, D)), jnp.float32)
    v = jnp.array(RNG.normal(size=(B, Hkv, Tk, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-4)


def test_flash_attention_padded_keys_ignored():
    """Keys past ``seq_k`` must contribute nothing: growing the key padding
    cannot change the output."""
    from repro.kernels.flash_attention import flash_attention_pallas
    B, H, Tk, D = 1, 2, 20, 16
    q = jnp.array(RNG.normal(size=(B, H, 1, D)), jnp.float32)
    k = jnp.array(RNG.normal(size=(B, H, Tk, D)), jnp.float32)
    v = jnp.array(RNG.normal(size=(B, H, Tk, D)), jnp.float32)
    pad = [(0, 0), (0, 0), (0, 12), (0, 0)]
    out_p = flash_attention_pallas(
        jnp.pad(q, [(0, 0), (0, 0), (0, 15), (0, 0)]),
        jnp.pad(k, pad, constant_values=9.0),
        jnp.pad(v, pad, constant_values=9.0),
        causal=False, block_q=16, block_k=16, seq_k=Tk, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out_p[:, :, :1]), np.asarray(ref),
                               atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("B,H,Hkv,Tq,Tk,D,window,causal", [
    (1, 2, 1, 1, 24, 16, 8, False),     # decode: Tq=1 against a window
    (2, 4, 2, 9, 40, 16, 16, True),     # causal ragged prefill + window
    (1, 2, 2, 40, 24, 16, 20, True),    # Tq > Tk, windowed (W >= Tq - Tk
                                        # so no query row is fully masked)
    (2, 2, 1, 12, 12, 32, 4, False),    # non-causal sliding window
])
def test_flash_attention_window_ragged(B, H, Hkv, Tq, Tk, D, window, causal):
    """Sliding-window parity on the shapes gqa_forward actually routes:
    ragged decode (Tq=1 and Tq>Tk) and non-causal windows must match the
    model-side ``causal_mask`` + ``_sdpa`` / ref oracle."""
    from repro.models.attention import _sdpa, causal_mask
    q = jnp.array(RNG.normal(size=(B, H, Tq, D)), jnp.float32)
    k = jnp.array(RNG.normal(size=(B, Hkv, Tk, D)), jnp.float32)
    v = jnp.array(RNG.normal(size=(B, Hkv, Tk, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=8, block_k=8, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-4)
    if causal:
        # cross-check against the model-side mask math in (B,T,H,hd) layout
        mask = causal_mask(Tq, Tk, window)
        sd = _sdpa(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                   jnp.swapaxes(v, 1, 2), mask, 1.0 / np.sqrt(D))
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(jnp.swapaxes(sd, 1, 2)),
                                   atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("kv_valid", [1, 7, 24])
def test_flash_attention_kv_valid_traced(kv_valid):
    """The decode gate: ``kv_valid`` is a traced runtime scalar that must
    truncate keys exactly like slicing would, including with a window."""
    B, H, Tk, D, W = 1, 2, 24, 16, 8
    q = jnp.array(RNG.normal(size=(B, H, 1, D)), jnp.float32)
    k = jnp.array(RNG.normal(size=(B, H, Tk, D)), jnp.float32)
    v = jnp.array(RNG.normal(size=(B, H, Tk, D)), jnp.float32)
    fn = jax.jit(lambda n: flash_attention(q, k, v, causal=False, window=W,
                                           kv_valid=n, interpret=True))
    out = fn(jnp.int32(kv_valid))
    ref = flash_attention_ref(q, k[:, :, :kv_valid], v[:, :, :kv_valid],
                              causal=False, window=W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-4)


def test_entropy_exit_tau_is_traced():
    """Changing tau must not recompile: tau rides in SMEM, so two taus over
    one jitted gate share a single compilation and still gate correctly."""
    x = jnp.array(RNG.normal(size=(8, 512)) * 2, jnp.float32)
    gate = jax.jit(lambda t: entropy_exit(x, t, interpret=True))
    with jax.log_compiles(False):
        H1, ex1 = gate(jnp.float32(0.2 * np.log(512)))
        n_compiles = gate._cache_size()
        H2, ex2 = gate(jnp.float32(0.95 * np.log(512)))
        assert gate._cache_size() == n_compiles == 1
    np.testing.assert_allclose(np.asarray(H1), np.asarray(H2), atol=1e-6)
    assert np.asarray(ex1).sum() <= np.asarray(ex2).sum()
    Hr = np.asarray(H1)
    np.testing.assert_array_equal(np.asarray(ex1),
                                  Hr < 0.2 * np.log(512))
    np.testing.assert_array_equal(np.asarray(ex2),
                                  Hr < 0.95 * np.log(512))


@pytest.mark.parametrize("B,V,block_v", [
    (8, 300, 128),      # vocab tail: 300 = 2*128 + 44
    (4, 128, 128),      # exact multiple
    (6, 512, 2048),     # single block wider than V
])
def test_entropy_exit_matches_softmax_entropy(B, V, block_v):
    """The Pallas gate must agree with ``core.losses.softmax_entropy`` — the
    definition the serve gate uses — including on non-multiple-of-block_v
    vocab tails."""
    from repro.core.losses import softmax_entropy
    x = jnp.array(RNG.normal(size=(B, V)) * 2, jnp.float32)
    tau = 0.6 * np.log(V)
    H, ex = entropy_exit(x, float(tau), block_v=block_v, interpret=True)
    Hr = softmax_entropy(x)
    np.testing.assert_allclose(np.asarray(H), np.asarray(Hr), atol=1e-4,
                               rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(ex),
                                  np.asarray(Hr) < float(tau))


def test_entropy_exit_agrees_with_serve_gate():
    """H < tau decisions from the kernel match ``make_serve_step``'s in-graph
    gate on real exit-head logits."""
    from repro import configs as configs_mod
    from repro.api.serve_session import serve_step_config
    from repro.core.spmd import make_serve_step
    from repro.models.backbone import init_backbone

    cfg = configs_mod.get("glm4-9b").smoke()
    tau = 0.9 * float(np.log(cfg.vocab_size))
    sc, _, _ = serve_step_config(cfg, tau=tau, boundary=0)
    params = init_backbone(jax.random.PRNGKey(0), cfg)
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, (3, 4)), jnp.int32)
    got = make_serve_step(sc, boundary=0)(params, tokens, None, None)

    from repro.models.backbone import backbone_forward
    e_logits = backbone_forward(params, cfg, tokens=tokens).exit_logits[0]
    B, T, V = e_logits.shape
    H, ex = entropy_exit(e_logits.reshape(B * T, V), tau, interpret=True)
    np.testing.assert_allclose(np.asarray(H).reshape(B, T),
                               np.asarray(got["entropy"]), atol=1e-4,
                               rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(ex).reshape(B, T),
                                  np.asarray(got["exited"]))
