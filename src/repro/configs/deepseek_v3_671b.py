"""deepseek-v3-671b [moe] — 61L d_model=7168, MLA (128H, kv_lora=512,
rope_dim=64), 3 dense-MLP prefix layers (d_ff=18432) then MoE layers with
1 shared + 256 routed experts top-8 (d_expert=2048), vocab=129280.
[arXiv:2412.19437]"""
from __future__ import annotations

from repro.config import HeteroProfile, MLAConfig, ModelConfig, MoEConfig

NUM_LAYERS = 61
DENSE_PREFIX = 3
EXITS = (15, 30, 45)


def config(sliding_window=None) -> ModelConfig:
    ffns = ("mlp",) * DENSE_PREFIX + ("moe",) * (NUM_LAYERS - DENSE_PREFIX)
    return ModelConfig(
        name="deepseek-v3-671b", arch_type="moe",
        num_layers=NUM_LAYERS, d_model=7168, num_heads=128, num_kv_heads=128,
        d_ff=18432, vocab_size=129280, head_dim=128,
        block_pattern=("mla",) * NUM_LAYERS, ffn_pattern=ffns,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048,
                      num_shared_experts=1, d_shared_expert=2048,
                      capacity_factor=1.25),
        exit_layers=EXITS, sliding_window=sliding_window,
        source="arXiv:2412.19437",
    )


def smoke() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name="deepseek-v3-671b-smoke", arch_type="moe",
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512, head_dim=32,
        block_pattern=("mla",) * 4, ffn_pattern=("mlp", "moe", "moe", "moe"),
        mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=16,
                      qk_rope_head_dim=16, v_head_dim=16),
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                      num_shared_experts=1, d_shared_expert=64),
        exit_layers=(2,), dtype=jnp.float32, param_dtype=jnp.float32,
        source="arXiv:2412.19437",
    )


def profile() -> HeteroProfile:
    return HeteroProfile(split_layers=(EXITS[0],) * 4 + (EXITS[1],) * 4
                         + (EXITS[2],) * 4)
