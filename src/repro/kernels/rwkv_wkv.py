"""Chunked RWKV6 WKV scan (Pallas, TPU target).

TPU adaptation of the Finch CUDA kernel (docs/DESIGN.md §2): instead of one thread
per channel marching token-by-token (GPU-shaped), we process the sequence in
chunks — quadratic MXU work inside a chunk plus a VMEM-resident recurrent
state (K x V per head) carried across sequential grid steps.  Per chunk, with
per-channel cumulative log-decay L_t = sum_{j<=t} log w_j:

    y_t  = (r_t * e^{L_{t-1}}) . S_chunkstart                 (inter)
         + sum_{i<t} (r_t * e^{L_{t-1}-L_i}) . k_i  v_i       (intra)
         + (r_t * u * k_t) . v_t                              (bonus diag)
    S'   = diag(e^{L_Q}) S + sum_i (k_i e^{L_Q - L_i}) v_i^T

Grid = (B*H, T/Q); the second axis is sequential so S lives in scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, sT_ref, s_scr, *,
                chunk: int):
    ic = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)                          # (Q, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)                        # log w_t <= 0
    u = u_ref[0].astype(jnp.float32)                          # (1, K) bonus

    L = jnp.cumsum(lw, axis=0)                                # (Q, K)
    L_prev = L - lw
    rw = r * jnp.exp(L_prev)                                  # decayed queries
    kw = k * jnp.exp(-L)                                      # advanced keys

    # intra-chunk, strictly-lower-triangular scores
    scores = jax.lax.dot_general(rw, kw, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    ti = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(tj < ti, scores, 0.0)
    y = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # bonus diagonal (current token, no decay, u-weighted)
    y += jnp.sum(r * u * k, axis=1, keepdims=True) * v
    # inter-chunk from the carried state
    y += jax.lax.dot_general(rw, s_scr[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_ref[0, ...] = y.astype(y_ref.dtype)

    # state update: S' = diag(e^{L_Q}) S + sum_i (k_i e^{L_Q - L_i}) v_i^T
    tail = jnp.exp(L[-1:, :] - L)                             # (Q, K)
    s_new = (jnp.exp(L[-1])[:, None] * s_scr[...]
             + jax.lax.dot_general(k * tail, v, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    s_scr[...] = s_new

    @pl.when(ic == nc - 1)
    def _emit_state():
        sT_ref[0, ...] = s_new                                # carry (K, K)


def rwkv_wkv_pallas(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    log_w: jnp.ndarray, u: jnp.ndarray, *,
                    chunk: int = 64, interpret: bool = False) -> jnp.ndarray:
    """r/k/v/log_w: (BH, T, K) flattened batch*heads; u: (BH, K).
    T must be a multiple of ``chunk`` (ops.py pads).  Returns
    ``(y (BH, T, K), S_T (BH, K, K))`` — the outputs plus the final carried
    state, so chunked prefill can seed the decode cache."""
    BH, T, K = r.shape
    assert T % chunk == 0
    grid = (BH, T // chunk)
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    seq_spec = pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, K), lambda b, c: (b, 0))],
        out_specs=[seq_spec,
                   pl.BlockSpec((1, K, K), lambda b, c: (b, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((BH, T, K), jnp.float32),
                   jax.ShapeDtypeStruct((BH, K, K), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((K, K), jnp.float32)],
        interpret=interpret,
    )(r, k, v, log_w, u)
