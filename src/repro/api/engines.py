"""Engine registry and the engine execution contract.

An *engine* is an execution backend for the paper's cooperative strategies:
a pure ``TrainState -> TrainState`` executor.  Engines never own training
state — they receive a state, run some rounds, and return the new state plus
per-round metrics, so states can be checkpointed, resumed, and handed
between engines freely (the resume-equivalence contract in docs/API.md).

Registered engines:

  * ``"reference"`` — per-client jitted loop, the paper-faithful oracle;
    supports every strategy including Sequential (Alg. 1).
  * ``"fused"``     — scan+vmap whole-chunk execution for Averaging /
    distributed (docs/ENGINES.md).
  * ``"spmd"``      — the fused round body staged under jit with mesh
    shardings: the global batch shards over the mesh's batch axes
    (``repro.api.spmd_engine``, built on the core/spmd.py cohort step).
    Needs a mesh (``TrainSession(..., mesh=...)``) or >1 visible device.

``resolve_engine("auto", ctx)`` picks the widest valid engine for the
session's strategy, data layout, and device topology (spmd on a mesh,
fused on one device, reference otherwise) instead of failing at runtime,
and reports *why* candidates were skipped (surfaced by
``TrainSession.engine_name`` so benchmark manifests record the real
execution path); naming an engine explicitly validates it at construction
and raises with the precise reason if it cannot run.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple, Type

import numpy as np

from repro.config import OptimizerConfig, SplitEEConfig
from repro.data.pipeline import batch_iterator, effective_batch_size
from repro.optim import make_schedule


# ---------------------------------------------------------------------------
# Session context: everything static an engine needs (model, configs, data).
# Host-side and immutable apart from the iterator cache, which is keyed by
# the state's ``batches_drawn`` cursor so engines stay pure w.r.t. state.
# ---------------------------------------------------------------------------


class DataCursor:
    """Seeded per-client batch streams addressed by draw count.

    ``align(cursor)`` positions every client's ``batch_iterator`` at the
    given number of already-drawn batches — reusing the live iterators when
    the cursor matches (the common run-after-run case) and otherwise
    rebuilding from the seed and replaying, which reproduces the exact
    upcoming batch (and augmentation RNG) sequence after a checkpoint
    restore or a state rewind."""

    def __init__(self, client_data: Sequence[Tuple[np.ndarray, np.ndarray]],
                 batch_size: int, seed: int, augment=None):
        self.client_data = client_data
        self.batch_size = batch_size
        self.seed = seed
        self.augment = augment
        self._iters: Optional[list] = None
        self._pos: Optional[Tuple[int, ...]] = None

    def align(self, cursor) -> None:
        want = tuple(int(c) for c in np.asarray(cursor))
        if self._pos == want:
            return
        self._iters = [
            batch_iterator(x, y, self.batch_size, seed=self.seed + i,
                           augment=self.augment)
            for i, (x, y) in enumerate(self.client_data)]
        for it, k in zip(self._iters, want):
            for _ in range(k):
                next(it)
        self._pos = want

    def draw(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        assert self._iters is not None, "align() before draw()"
        batch = next(self._iters[i])
        pos = list(self._pos)
        pos[i] += 1
        self._pos = tuple(pos)
        return batch


class SessionContext:
    """Static bundle shared by a session and its engine: the model adapter,
    configs, derived schedule/LR constants, and the data cursor."""

    def __init__(self, model, splitee_cfg: SplitEEConfig,
                 opt_cfg: OptimizerConfig,
                 client_data: Sequence[Tuple[np.ndarray, np.ndarray]],
                 batch_size: int, *, augment=None, seed: int = 0,
                 mesh=None, grad_mode: str = "eq1", recipe=None):
        if grad_mode not in ("eq1", "sum"):
            raise ValueError(f"unknown grad_mode {grad_mode!r}; expected "
                             f"'eq1' or 'sum'")
        # resolve eagerly so a bad --recipe name dies at the facade, not
        # inside an engine; the spmd engine reads the resolved dataclass
        from repro.launch.shardings import recipe_name, resolve_recipe
        self.recipe = resolve_recipe(recipe)
        self.recipe_name = recipe_name(recipe)
        self.model = model
        self.cfg = splitee_cfg
        self.opt_cfg = opt_cfg
        self.client_data = client_data
        self.batch_size = batch_size
        self.augment = augment
        self.seed = seed
        self.mesh = mesh
        self.grad_mode = grad_mode

        self.profile = splitee_cfg.profile
        self.strategy = splitee_cfg.strategy
        self.N = self.profile.num_groups
        if len(client_data) != self.N:
            raise ValueError(f"profile has {self.N} client groups but "
                             f"{len(client_data)} data shards were given")
        self.schedule = make_schedule(opt_cfg)
        self.server_lr_div = splitee_cfg.resolved_server_lr_divisor()
        self.data = DataCursor(client_data, batch_size, seed, augment)


# ---------------------------------------------------------------------------
# Engine base + registry
# ---------------------------------------------------------------------------


class Engine:
    """Base class: a pure ``state -> state`` executor bound to a context.

    Instances may cache compiled functions (jitted steps, scan chunks) —
    caches are derived from the immutable context, never from state."""

    name: str = "?"

    def __init__(self, ctx: SessionContext):
        reason = self.supports(ctx)
        if reason:
            raise ValueError(reason)
        self.ctx = ctx

    @classmethod
    def supports(cls, ctx: SessionContext) -> Optional[str]:
        """``None`` if this engine can run the session, else a human-readable
        reason (used both for auto-selection and for construction errors)."""
        return None

    def run(self, state, rounds: int, local_epochs: int = 1,
            log_every: int = 0, chunk_rounds: int = 0):
        """Train ``rounds`` rounds from ``state``; returns
        ``(new_state, [RoundMetrics])``.  Must not mutate ``state``."""
        raise NotImplementedError


_REGISTRY: Dict[str, Type[Engine]] = {}

#: auto-selection preference: widest engine first (spmd wants a mesh or >1
#: device; fused wants averaging/distributed; reference takes everything)
AUTO_ORDER = ("spmd", "fused", "reference")


def register_engine(name: str) -> Callable[[Type[Engine]], Type[Engine]]:
    def deco(cls: Type[Engine]) -> Type[Engine]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_engine(name: str) -> Type[Engine]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown engine {name!r}; registered engines: "
                         f"{available_engines()}") from None


def available_engines() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_engine(name: str, ctx: SessionContext
                   ) -> Tuple[Type[Engine], Optional[str]]:
    """Resolve an engine name (or ``"auto"``) against a session context.

    Returns ``(engine_cls, selection_note)``.  ``"auto"`` picks the first
    engine in :data:`AUTO_ORDER` whose ``supports`` accepts the context —
    e.g. a single-device averaging session falls back from spmd to fused,
    and Sequential-strategy sessions fall back to the reference engine
    instead of raising the way an explicit ``engine="fused"`` request does.
    When auto-selection skipped wider candidates, ``selection_note`` says
    why (e.g. ``"spmd unavailable: ... only 1 device visible"``) so the
    real execution path is auditable (``TrainSession.engine_name``);
    explicit requests resolve with ``selection_note=None`` or raise."""
    if name == "auto":
        skipped: List[Tuple[List[str], str]] = []
        for cand in AUTO_ORDER:
            cls = _REGISTRY[cand]
            reason = cls.supports(ctx)
            if reason is None:
                # engines sharing a reason (e.g. spmd+fused on Sequential)
                # collapse into one entry so the note stays readable
                note = "; ".join(f"{'/'.join(names)} unavailable: {r}"
                                 for names, r in skipped) or None
                return cls, note
            if skipped and skipped[-1][1] == reason:
                skipped[-1][0].append(cand)
            else:
                skipped.append(([cand], reason))
        raise ValueError("no registered engine supports this session ("
                         + "; ".join(f"{'/'.join(names)}: {r}"
                                     for names, r in skipped) + ")")
    cls = get_engine(name)
    reason = cls.supports(ctx)
    if reason:
        raise ValueError(reason)
    return cls, None


def cohort_layout(split_layers: Sequence[int]
                  ) -> Tuple[Tuple[int, ...], Dict[int, List[int]]]:
    """Group client indices into cohorts by split layer: returns the sorted
    distinct cut layers and ``{li: [client indices]}``."""
    lis = tuple(sorted(set(split_layers)))
    lanes = {li: [i for i, l in enumerate(split_layers) if l == li]
             for li in lis}
    return lis, lanes


def ragged_cohort_reason(ctx: SessionContext) -> Optional[str]:
    """Cohort lanes are stacked into one ``[k, B, ...]`` tensor, so clients
    sharing a cut layer must emit equal effective batch sizes; return the
    offending cohort's description if not (the reference engine has no such
    constraint)."""
    _, lanes = cohort_layout(ctx.profile.split_layers)
    for li, members in lanes.items():
        bs = {i: effective_batch_size(len(ctx.client_data[i][0]),
                                      ctx.batch_size)
              for i in members}
        if len(set(bs.values())) > 1:
            return (f"cohort l_i={li} mixes effective batch sizes {bs} "
                    f"(batch_size={ctx.batch_size} clamped to shard "
                    f"length); equalize client shards or use the "
                    f"reference engine")
    return None
