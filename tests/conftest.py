import os

# Tests run on the default single CPU device; ONLY dryrun.py forces 512
# placeholder devices.  A couple of sharding tests request 8 local devices —
# they spawn subprocesses instead of mutating this process's device count.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, MoEConfig, MLAConfig, SSMConfig

F32 = dict(dtype=jnp.float32, param_dtype=jnp.float32)


@pytest.fixture(scope="session")
def tiny_dense():
    return ModelConfig(name="tiny-dense", arch_type="dense", num_layers=4,
                       d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                       vocab_size=97, exit_layers=(1, 2), **F32)


@pytest.fixture(scope="session")
def tiny_swa():
    return ModelConfig(name="tiny-swa", arch_type="dense", num_layers=3,
                       d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                       vocab_size=97, sliding_window=6, **F32)


@pytest.fixture(scope="session")
def tiny_moe():
    return ModelConfig(name="tiny-moe", arch_type="moe", num_layers=3,
                       d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
                       vocab_size=97, ffn_pattern=("mlp", "moe", "moe"),
                       moe=MoEConfig(num_experts=4, top_k=2, d_expert=32,
                                     num_shared_experts=1, d_shared_expert=32,
                                     # no-drop capacity: capacity-factor MoE
                                     # output is batch-context dependent when
                                     # tokens drop, which would break the
                                     # prefill/decode consistency check
                                     capacity_factor=8.0),
                       exit_layers=(1,), **F32)


@pytest.fixture(scope="session")
def tiny_mamba():
    return ModelConfig(name="tiny-mamba", arch_type="ssm", num_layers=3,
                       d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
                       vocab_size=97, block_pattern=("mamba2",) * 3,
                       ffn_pattern=("none",) * 3,
                       ssm=SSMConfig(d_state=16, head_dim=16, chunk_size=4),
                       exit_layers=(1,), **F32)


@pytest.fixture(scope="session")
def tiny_rwkv():
    return ModelConfig(name="tiny-rwkv", arch_type="ssm", num_layers=3,
                       d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
                       vocab_size=97, block_pattern=("rwkv6",) * 3,
                       ffn_pattern=("rwkv_cm",) * 3,
                       ssm=SSMConfig(kind="rwkv6", head_dim=16, chunk_size=4),
                       exit_layers=(1,), **F32)
