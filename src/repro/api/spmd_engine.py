"""SPMD engine: the fused round body staged under jit with mesh shardings,
as a pure ``TrainState -> TrainState`` executor (see docs/ENGINES.md).

This is the scaling story for the Averaging/distributed strategies: the
chunk function the fused engine scans on one device is compiled with
explicit `jax.sharding.NamedSharding` constraints instead —

  * the **global batch** (every cohort's pre-staged ``[rounds, E, k, B,
    ...]`` minibatch tensor) shards its per-lane batch dimension ``B`` over
    the mesh's batch axes (``("pod", "data")`` where present,
    ``launch.mesh.batch_axes``), so each device computes the forward/backward
    for its slice of every client's minibatch;
  * parameters, Adam moments, and BN statistics **replicate**; XLA's SPMD
    partitioner turns the per-minibatch gradient reductions into
    ``all-reduce`` collectives over the batch axes, and the in-graph Eq. (1)
    aggregation stays collective-free on the replicated carry.

The math is byte-for-byte the fused engine's (the same
``core.spmd.make_cohort_train_step`` under the same scanned round body), so
spmd ``eq1`` is cross-checkable against the reference engine to float32
reduction tolerance — including ``aggregate_every`` boundaries and
checkpoint/resume hand-offs between engines (tests/test_spmd_engine.py).

Meshes: pass one explicitly (``TrainSession(..., mesh=...)`` — e.g.
``launch.mesh.make_production_mesh()``) or let the engine build the default
data-parallel mesh over every visible device.  On a CPU container, expose
fake devices first: ``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.api.engines import SessionContext, register_engine
from repro.api.fused_engine import FusedEngine
from repro.data.pipeline import effective_batch_size
from repro.launch.mesh import axis_sizes, batch_axes
from repro.launch.shardings import to_named


def default_data_mesh():
    """A 1-D data-parallel mesh over every visible device (the host-CPU
    test topology and the single-process accelerator default).  Production
    launches pass ``launch.mesh.make_production_mesh()`` instead."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def resolve_mesh(ctx: SessionContext):
    """The mesh this session's spmd engine runs on: the explicit
    ``ctx.mesh`` when one was supplied, else the default data mesh."""
    return ctx.mesh if ctx.mesh is not None else default_data_mesh()


def data_parallelism(mesh) -> int:
    """Total batch-axis parallelism of ``mesh`` (product of the ``pod`` and
    ``data`` axis sizes present)."""
    sizes = axis_sizes(mesh)
    return int(np.prod([sizes[a] for a in batch_axes(mesh)]))


@register_engine("spmd")
class SpmdEngine(FusedEngine):
    """Mesh-sharded execution of the fused scan+vmap round body."""

    def __init__(self, ctx: SessionContext):
        super().__init__(ctx)
        self.mesh = resolve_mesh(ctx)
        ax = batch_axes(self.mesh)
        ax = ax if len(ax) > 1 else ax[0]
        # one spec serves every staged leaf: [rounds, E, k, B, ...] — the
        # per-lane batch dim shards, trailing feature dims replicate
        self._replicated = to_named(P(), self.mesh)
        self._batch_sharding = to_named(P(None, None, None, ax), self.mesh)

    @classmethod
    def supports(cls, ctx: SessionContext) -> Optional[str]:
        reason = super().supports(ctx)           # strategy + ragged cohorts
        if reason:
            return reason
        if ctx.mesh is None and len(jax.devices()) < 2:
            return ("needs a mesh (TrainSession(..., mesh=...)) or >1 "
                    "visible device (e.g. XLA_FLAGS=--xla_force_host_"
                    "platform_device_count=4); only 1 device visible")
        mesh = resolve_mesh(ctx)
        dp = data_parallelism(mesh)
        if dp < 2:
            return (f"mesh {axis_sizes(mesh)} has no parallelism on its "
                    f"batch axes {batch_axes(mesh)}")
        for i, (xd, _) in enumerate(ctx.client_data):
            eb = effective_batch_size(len(xd), ctx.batch_size)
            if eb % dp != 0:
                return (f"client {i}'s effective batch size {eb} does not "
                        f"divide over the data-parallel size {dp}; adjust "
                        f"batch_size or the mesh")
        return None

    # ------------------------------------------------------------- staging
    def _compile_chunk(self, chunk: Callable) -> Callable:
        """Jit the scanned round body with mesh shardings: carry (params /
        moments / BN stats) and per-round losses replicated, the staged
        batch tensors sharded over the batch axes.  The carry is still
        donated, so long chunks run in place."""
        rep, bsh = self._replicated, self._batch_sharding
        return jax.jit(chunk,
                       in_shardings=(rep, rep, bsh, bsh),
                       out_shardings=(rep, rep),
                       donate_argnums=(0,))

    def _put_batch(self, arr):
        """Host-staged batch numpy -> its batch sharding directly, so each
        device receives only its slice (never materializing the whole
        chunk on one device)."""
        return jax.device_put(arr, self._batch_sharding)

    def _stack_carry(self, clients, copts, servers, sopts):
        """Replicate the stacked carry across the mesh up front (avoids an
        implicit single-device -> replicated reshard inside the jit and
        keeps donation effective)."""
        carry = super()._stack_carry(clients, copts, servers, sopts)
        return jax.device_put(carry, self._replicated)
