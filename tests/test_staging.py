"""The overlapped staging pipeline (``data/staging.py``) and its fused-
engine integration: the double buffer must be invisible to the math —
bit-identical trajectories with the pipeline on or off, across chunk
boundaries, aggregate_every straddles, and mid-run checkpoint resume —
while the budget knobs fail loudly on misconfiguration.  The spmd-engine
half of the contract lives in tests/test_spmd_engine.py (subprocess
4-device harness); the 2-process variant in tests/test_distributed.py.
"""
import threading
import time

import numpy as np
import pytest

from repro.api import TrainSession
from repro.config import HeteroProfile, OptimizerConfig, SplitEEConfig
from repro.core.splitee import MLPSplitModel
from repro.data.pipeline import batch_iterator, prestage_batches
from repro.data.staging import StagedChunkPipeline, StageStats


# ---------------------------------------------------------------------------
# StagedChunkPipeline unit behavior
# ---------------------------------------------------------------------------


def test_pipeline_preserves_plan_order():
    staged = []

    def stage(n):
        staged.append(n)
        return ("chunk", n)

    plan = [3, 1, 4, 1, 5]
    p = StagedChunkPipeline(stage, plan)
    try:
        got = []
        for _ in plan:
            got.append(p.get())
            p.release()
        assert got == [("chunk", n) for n in plan]
        assert staged == plan                  # staged strictly in order
        assert p.stats.chunks == len(plan)
    finally:
        p.close()


def test_pipeline_bounds_inflight_chunks_to_depth():
    """The producer never runs ahead of the consumer by more than
    ``depth`` staged chunks (the staging-budget contract)."""
    inflight = []
    lock = threading.Lock()
    live = [0]

    def stage(n):
        with lock:
            live[0] += 1
            inflight.append(live[0])
        return n

    p = StagedChunkPipeline(stage, [1] * 8, depth=2)
    try:
        for _ in range(8):
            p.get()
            time.sleep(0.01)                   # let the producer run ahead
            with lock:
                live[0] -= 1
            p.release()
        assert max(inflight) <= 2
    finally:
        p.close()


def test_pipeline_depth_below_two_rejected():
    with pytest.raises(ValueError, match="depth"):
        StagedChunkPipeline(lambda n: n, [1, 2], depth=1)


def test_pipeline_propagates_producer_errors():
    def stage(n):
        if n == 2:
            raise RuntimeError("disk on fire")
        return n

    p = StagedChunkPipeline(stage, [1, 2, 3])
    assert p.get() == 1
    p.release()
    with pytest.raises(RuntimeError, match="disk on fire"):
        p.get()
    p.close()                                  # idempotent after the error
    p.close()


def test_pipeline_close_unblocks_parked_producer():
    p = StagedChunkPipeline(lambda n: n, [1] * 10, depth=2)
    assert p.get() == 1
    p.close()
    assert not p._thread.is_alive()


def test_pipeline_serial_mode_stages_on_demand():
    staged = []
    p = StagedChunkPipeline(lambda n: staged.append(n) or n, [7, 8],
                            overlap=False)
    assert staged == []                        # nothing eager
    assert p.get() == 7 and staged == [7]
    p.release()
    assert p.get() == 8
    p.close()
    assert p.stats.overlap_fraction == 0.0     # serial hides nothing
    assert p.stats.wait_s == p.stats.stage_s


def test_stage_stats_overlap_fraction_bounds():
    s = StageStats(chunks=3, stage_s=2.0, wait_s=0.5)
    assert s.overlap_fraction == pytest.approx(0.75)
    assert StageStats().overlap_fraction == 0.0
    assert StageStats(stage_s=1.0, wait_s=5.0).overlap_fraction == 0.0
    d = s.as_dict()
    assert d["chunks"] == 3 and d["overlap_fraction"] == pytest.approx(0.75)


# ---------------------------------------------------------------------------
# prestage_batches in-place fill
# ---------------------------------------------------------------------------


def test_prestage_fills_caller_buffers_in_place():
    x = np.arange(120, dtype=np.float32).reshape(40, 3)
    y = np.arange(40, dtype=np.int32)
    want = prestage_batches(batch_iterator(x, y, 8, seed=3), 3, 2)
    assert want[0].shape == (3, 2, 8, 3) and want[1].shape == (3, 2, 8)

    # same draws into caller-owned (non-contiguous view) buffers
    bx = np.empty((3, 2, 5, 8, 3), np.float32)
    by = np.empty((3, 2, 5, 8), np.int32)
    got = prestage_batches(batch_iterator(x, y, 8, seed=3), 3, 2,
                           out=(bx[:, :, 2], by[:, :, 2]))
    assert got[0].base is bx and got[1].base is by   # filled in place
    np.testing.assert_array_equal(bx[:, :, 2], want[0])
    np.testing.assert_array_equal(by[:, :, 2], want[1])


# ---------------------------------------------------------------------------
# fused-engine integration
# ---------------------------------------------------------------------------


def _make(engine="fused", aggregate_every=2):
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(3, 16)) * 2.0
    y = rng.integers(0, 3, 600).astype(np.int32)
    x = (centers[y] + rng.normal(size=(600, 16))).astype(np.float32)
    model = MLPSplitModel(in_dim=16, hidden=32, num_classes=3, num_layers=4,
                          seed=0)
    parts = [(x[i::4], y[i::4]) for i in range(4)]
    return model, parts, TrainSession.from_config(
        model,
        SplitEEConfig(profile=HeteroProfile((1, 2, 2, 3)),
                      strategy="averaging", aggregate_every=aggregate_every),
        OptimizerConfig(lr=3e-3, total_steps=60), parts, batch_size=64,
        engine=engine)


def _max_state_delta(a, b):
    import jax

    return max(float(np.max(np.abs(np.asarray(u, np.float64)
                                   - np.asarray(v, np.float64))))
               for u, v in zip(jax.tree.leaves(a.state),
                               jax.tree.leaves(b.state)))


def test_fused_overlap_on_off_bit_identical():
    """Pipeline on vs off over a multi-chunk plan with chunk boundaries
    straddling aggregate_every=2 rounds: exactly zero divergence in
    params, opt state, and per-round metrics."""
    _, _, on = _make()
    _, _, off = _make()
    on.engine.overlap_staging = True
    off.engine.overlap_staging = False
    # chunk_rounds=3 with aggregate_every=2: the round-3 aggregation
    # boundary opens chunk 2
    on.train(6, local_epochs=2, chunk_rounds=3)
    off.train(6, local_epochs=2, chunk_rounds=3)
    assert _max_state_delta(on, off) == 0.0
    for a, b in zip(on.history, off.history):
        assert (a.client_loss, a.server_loss) == (b.client_loss,
                                                  b.server_loss)
    assert on.engine.last_stage_stats["overlap"] is True
    assert on.engine.last_stage_stats["chunks"] == 2
    assert off.engine.last_stage_stats["overlap"] is False
    assert off.engine.last_stage_stats["overlap_fraction"] == 0.0


def test_fused_overlap_resume_from_mid_run_checkpoint(tmp_path):
    """A checkpoint written mid-run under the pipeline resumes into the
    serial engine's uninterrupted trajectory (and vice versa): the
    data-cursor bookkeeping is pipeline-invariant."""
    model, parts, serial = _make()
    serial.engine.overlap_staging = False
    serial.train(6, local_epochs=2, chunk_rounds=2)

    _, _, mid = _make()
    mid.engine.overlap_staging = True
    mid.train(3, local_epochs=2, chunk_rounds=2)
    mid.save(str(tmp_path / "ck"))
    cont = TrainSession.restore(str(tmp_path / "ck"), model, parts,
                                engine="fused")
    cont.engine.overlap_staging = True
    cont.train(3, local_epochs=2, chunk_rounds=2)
    assert _max_state_delta(serial, cont) <= 1e-5


def test_overlap_env_kill_switch(monkeypatch):
    _, _, tr = _make()
    eng = tr.engine
    monkeypatch.setenv("REPRO_OVERLAP_STAGING", "0")
    assert eng._overlap_enabled() is False
    monkeypatch.setenv("REPRO_OVERLAP_STAGING", "off")
    assert eng._overlap_enabled() is False
    monkeypatch.setenv("REPRO_OVERLAP_STAGING", "1")
    assert eng._overlap_enabled() is True
    monkeypatch.delenv("REPRO_OVERLAP_STAGING")
    eng.overlap_staging = False
    assert eng._overlap_enabled() is False
    tr.train(2)                                # serial path end to end
    assert tr.engine.last_stage_stats["overlap"] is False


def test_auto_plan_subdivides_for_the_pipeline():
    """chunk_rounds=0 under a roomy budget used to produce one whole-run
    chunk; with overlap on it subdivides (nothing to overlap otherwise),
    while an explicit chunk_rounds is always honored exactly."""
    _, _, tr = _make()
    eng = tr.engine
    assert eng._chunk_plan(8, 0, 1, overlap=True) == [2, 2, 2, 2]
    assert eng._chunk_plan(8, 0, 1, overlap=False) == [8]
    assert eng._chunk_plan(8, 3, 1, overlap=True) == [3, 3, 2]
    assert eng._chunk_plan(1, 0, 1, overlap=True) == [1]
    # the budget still caps chunk size before any subdivision; under
    # overlap it is divided by pipeline_depth (default 2) so the depth
    # resident chunks *together* stay within stage_budget_bytes
    eng.stage_budget_bytes = eng._round_stage_bytes(1) * 6
    assert eng._chunk_plan(8, 0, 1, overlap=True) == [3, 3, 2]
    assert eng._chunk_plan(8, 0, 1, overlap=False) == [6, 2]
    assert eng._auto_chunk_rounds(8, 1, overlap=True) == 3
    assert eng._auto_chunk_rounds(8, 1) == 6


# ---------------------------------------------------------------------------
# staging-budget validation
# ---------------------------------------------------------------------------


def test_stage_budget_must_be_strictly_positive(monkeypatch):
    _, _, tr = _make()
    eng = tr.engine
    for bad in (0, -1):
        eng.stage_budget_bytes = bad
        with pytest.raises(ValueError, match="stage_budget_bytes"):
            eng._auto_chunk_rounds(4, 1)
    eng.stage_budget_bytes = type(eng).stage_budget_bytes
    monkeypatch.setenv("REPRO_STAGE_BUDGET_MB", "0")
    with pytest.raises(ValueError, match="REPRO_STAGE_BUDGET_MB"):
        eng._auto_chunk_rounds(4, 1)
    monkeypatch.setenv("REPRO_STAGE_BUDGET_MB", "-5")
    with pytest.raises(ValueError, match="REPRO_STAGE_BUDGET_MB"):
        eng._auto_chunk_rounds(4, 1)
    monkeypatch.setenv("REPRO_STAGE_BUDGET_MB", "lots")
    with pytest.raises(ValueError, match="REPRO_STAGE_BUDGET_MB"):
        eng._auto_chunk_rounds(4, 1)
    monkeypatch.setenv("REPRO_STAGE_BUDGET_MB", "64")
    assert eng._auto_chunk_rounds(4, 1) == 4   # valid values still work
