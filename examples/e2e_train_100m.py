"""End-to-end driver: train a ~100M-parameter transformer with the fused
SPMD Hetero-SplitEE step for a few hundred steps on synthetic structured LM
data, with cosine LR, checkpointing, and per-boundary exit-loss reporting.

Defaults are sized for this CPU container (~100M params, 300 steps).  On a
real TPU mesh the identical step runs under the production shardings
(launch/dryrun.py proves lowering for every assigned arch x shape).

  PYTHONPATH=src python examples/e2e_train_100m.py --steps 300
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.config import (HeteroProfile, ModelConfig, OptimizerConfig,
                          SplitEEConfig, TrainConfig)
from repro.core.spmd import (StepConfig, boundary_ids_for_batch,
                             make_train_step)
from repro.data.synthetic import SyntheticLMDataset
from repro.models.backbone import init_backbone
from repro.optim import adam_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=640)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--vocab", type=int, default=32000)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-mode", default="eq1", choices=["eq1", "sum"])
    ap.add_argument("--checkpoint", default="experiments/artifacts/e2e_100m")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    L = args.layers
    cfg = ModelConfig(
        name="e2e-100m", arch_type="dense", num_layers=L,
        d_model=args.d_model, num_heads=args.d_model // 64,
        num_kv_heads=max(1, args.d_model // 128), d_ff=4 * args.d_model,
        vocab_size=args.vocab, exit_layers=(L // 4, L // 2, 3 * L // 4),
        dtype=jnp.float32, param_dtype=jnp.float32)
    profile = HeteroProfile(
        split_layers=(L // 4,) * 4 + (L // 2,) * 4 + (3 * L // 4,) * 4)

    sc = StepConfig(
        model=cfg, splitee=SplitEEConfig(profile=profile),
        train=TrainConfig(optimizer=OptimizerConfig(
            lr=args.lr, total_steps=args.steps, warmup_steps=20,
            schedule="cosine")),
        grad_mode=args.grad_mode)

    params = init_backbone(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {L}L d={args.d_model} vocab={args.vocab}  "
          f"params={n_params / 1e6:.1f}M  grad_mode={args.grad_mode}")
    print(f"hetero profile (12 clients): {profile.split_layers}")

    opt = adam_init(params, sc.train.optimizer)
    step_fn = jax.jit(make_train_step(sc))
    ds = SyntheticLMDataset(vocab_size=args.vocab, seq_len=args.seq,
                            structure=0.9, seed=0)
    sids = boundary_ids_for_batch(profile, cfg, args.batch)

    t0, losses = time.time(), []
    for step, (toks, labels) in enumerate(ds.batches(args.batch, args.steps)):
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels),
                 "split_ids": sids}
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["server_loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            cl = " ".join(f"b{i}={float(m[f'client_loss/b{i}']):.3f}"
                          for i in range(3))
            tok_s = (step + 1) * args.batch * args.seq / (time.time() - t0)
            print(f"step {step:4d}  server={losses[-1]:.4f}  {cl}  "
                  f"lr={float(m['lr']):.2e}  {tok_s:,.0f} tok/s")

    print(f"\nloss: first={losses[0]:.4f}  last={np.mean(losses[-10:]):.4f}")
    if args.checkpoint:
        # opt state + step counter ride along (same layout launch/train.py
        # restores with --resume)
        save_pytree(args.checkpoint, {"params": params, "opt": opt},
                    metadata={"steps": args.steps,
                              "final_loss": float(np.mean(losses[-10:]))})
        print(f"checkpoint -> {args.checkpoint}.npz")


if __name__ == "__main__":
    main()
