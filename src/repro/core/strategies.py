"""Shared training-step builders + the legacy ``HeteroTrainer`` shim.

The paper-faithful per-client training loop now lives in
``repro.api.reference_engine.ReferenceEngine`` as a pure
``TrainState -> TrainState`` executor behind the :class:`repro.api.TrainSession`
facade; this module keeps what both engines share:

  * :func:`make_client_step` / :func:`make_server_step` — pure functions of
    ``(pytrees, batch, lr)`` closed over the model/optimizer config only.
    The reference engine jits them one client at a time (the paper-faithful
    oracle); the fused engine vmaps the same functions over stacked client
    cohorts, so every engine runs numerically identical math.
  * :class:`RoundMetrics` — the per-round metric record.
  * :class:`HeteroTrainer` — a deprecation shim with the pre-``TrainSession``
    constructor and attribute surface (``.clients``, ``.servers``,
    ``.history``, ...), delegating to a session on the reference engine.
    New code should use ``repro.api.TrainSession`` directly.

Gradients never flow from server to client (``h_i`` enters the server step
as data), and every model is initialized from the same random seed via the
adapters in ``core/splitee.py`` (paper §III-B).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import numpy as np

from repro.config import OptimizerConfig, SplitEEConfig
from repro.core.losses import softmax_cross_entropy
from repro.optim import adam_update


@dataclass
class RoundMetrics:
    round: int
    client_loss: float
    server_loss: float


# ---------------------------------------------------------------------------
# Shared step-builders
# ---------------------------------------------------------------------------


def make_client_step(model, opt_cfg: OptimizerConfig) -> Callable:
    """(trainable, state, opt, x, y, lr) ->
    (trainable, state, opt, h, loss) — Alg. 1/2 lines 6-11."""

    def loss_fn(trainable, state, x, y):
        h, logits, new_state = model.client_forward(trainable, state, x,
                                                    train=True)
        return softmax_cross_entropy(logits, y), (h, new_state)

    def step(trainable, state, opt, x, y, lr):
        (loss, (h, new_state)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(trainable, state, x, y)
        trainable, opt = adam_update(trainable, grads, opt, opt_cfg, lr)
        return trainable, new_state, opt, h, loss

    return step


def make_server_step(model, opt_cfg: OptimizerConfig, li: int) -> Callable:
    """(trainable, state, opt, h, y, lr) ->
    (trainable, state, opt, loss) — Alg. 1/2 lines 12-16; ``h`` enters as
    data, so no gradient ever flows back to the client."""

    def loss_fn(trainable, state, h, y):
        logits, new_state = model.server_forward(trainable, state, h, li,
                                                 train=True)
        return softmax_cross_entropy(logits, y), new_state

    def step(trainable, state, opt, h, y, lr):
        (loss, new_state), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(trainable, state, h, y)
        trainable, opt = adam_update(trainable, grads, opt, opt_cfg, lr)
        return trainable, new_state, opt, loss

    return step


# ---------------------------------------------------------------------------
# Legacy trainer shim
# ---------------------------------------------------------------------------


class HeteroTrainer:
    """Deprecated: thin shim over ``repro.api.TrainSession`` pinned to the
    ``"reference"`` engine.  Exposes the historical mutable-attribute surface
    as read-only views of the session's ``TrainState``."""

    _ENGINE = "reference"

    def __init__(self, model, splitee_cfg: SplitEEConfig,
                 opt_cfg: OptimizerConfig,
                 client_data: Sequence[Tuple[np.ndarray, np.ndarray]],
                 batch_size: int, *, augment=None, seed: int = 0):
        warnings.warn(
            f"{type(self).__name__} is deprecated; use repro.api."
            f"TrainSession (engine={self._ENGINE!r}) — see docs/API.md",
            DeprecationWarning, stacklevel=2)
        from repro.api import TrainSession
        self.session = TrainSession(model, splitee_cfg, opt_cfg, client_data,
                                    batch_size, engine=self._ENGINE,
                                    augment=augment, seed=seed)

    # ------------------------------------------------- legacy attribute API
    @property
    def model(self):
        return self.session.ctx.model

    @property
    def cfg(self) -> SplitEEConfig:
        return self.session.ctx.cfg

    @property
    def opt_cfg(self) -> OptimizerConfig:
        return self.session.ctx.opt_cfg

    @property
    def profile(self):
        return self.session.ctx.profile

    @property
    def strategy(self) -> str:
        return self.session.ctx.strategy

    @property
    def N(self) -> int:
        return self.session.ctx.N

    @property
    def schedule(self):
        return self.session.ctx.schedule

    @property
    def server_lr_div(self) -> float:
        return self.session.ctx.server_lr_div

    @property
    def history(self) -> List[RoundMetrics]:
        return self.session.history

    # tuples, not lists: the old API's in-place writes (tr.clients[0] = ...)
    # can no longer take effect — raising beats silently dropping them
    @property
    def clients(self) -> Tuple[Dict[str, Any], ...]:
        return self.session.state.clients

    @property
    def client_opts(self) -> Tuple[Any, ...]:
        return self.session.state.client_opts

    @property
    def servers(self) -> Tuple[Dict[str, Any], ...]:
        return self.session.state.servers

    @property
    def server_opts(self) -> Tuple[Any, ...]:
        return self.session.state.server_opts

    @property
    def _round(self) -> int:
        return self.session.round

    # ------------------------------------------------------------ training
    def train_round(self, local_epochs: int = 1) -> RoundMetrics:
        return self.session.train(1, local_epochs)[-1]

    def run(self, rounds: int, local_epochs: int = 1, log_every: int = 0,
            **kw) -> List[RoundMetrics]:
        return self.session.run(rounds, local_epochs, log_every, **kw)

    # ---------------------------------------------------------------- eval
    def evaluate(self, x: np.ndarray, y: np.ndarray, batch_size: int = 512
                 ) -> Dict[str, Any]:
        return self.session.evaluate(x, y, batch_size)

    def evaluate_adaptive(self, x: np.ndarray, y: np.ndarray, tau: float,
                          batch_size: int = 512) -> Dict[str, Any]:
        return self.session.evaluate_adaptive(x, y, tau, batch_size)
