"""Minimal sharding-aware pytree checkpointing (no orbax in this container).

Arrays are gathered to host (``jax.device_get`` fetches fully-replicated or
addressable shards; on multi-host deployments call under
``jax.experimental.multihost_utils`` gather first), flattened by key-path and
stored in a single ``.npz`` plus a JSON manifest for structure and dtypes.
"""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import numpy as np


def _flatten(tree: Any):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)
    flat, treedef = leaves_with_paths
    keyed = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.name == "bfloat16":     # npz cannot store ml_dtypes
            arr = arr.astype(np.float32)     # lossless widening; manifest +
        keyed[key] = arr                     # `like` dtype restore narrows
    return keyed, treedef


def save_pytree(path: str, tree: Any, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    keyed, _ = _flatten(tree)
    np.savez(path + ".npz", **keyed)
    manifest = {
        "keys": sorted(keyed.keys()),
        "dtypes": {k: str(v.dtype) for k, v in keyed.items()},
        "shapes": {k: list(v.shape) for k, v in keyed.items()},
        "metadata": metadata or {},
    }
    with open(path + ".json", "w") as f:
        json.dump(manifest, f, indent=1)


def load_pytree(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (arrays replaced by loaded
    values; dtypes cast to match ``like``)."""
    data = np.load(path + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for keypath, leaf in flat:
        key = "/".join(str(p) for p in keypath)
        arr = data[key]
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
