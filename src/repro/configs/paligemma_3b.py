"""paligemma-3b [vlm] — SigLIP vision tower (stubbed: 256 patch embeddings of
dim 1152 via ``input_specs``) + 18L gemma decoder: d_model=2048 8H (GQA kv=1)
d_ff=16384 vocab=257216, head_dim=256.  [arXiv:2407.07726]"""
from __future__ import annotations

from repro.config import HeteroProfile, ModelConfig

EXITS = (5, 9, 13)


def config(sliding_window=None) -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b", arch_type="vlm",
        num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
        d_ff=16384, vocab_size=257216, head_dim=256,
        act="gelu", exit_layers=EXITS, sliding_window=sliding_window,
        source="arXiv:2407.07726",
    )


def smoke() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name="paligemma-3b-smoke", arch_type="vlm",
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=1,
        d_ff=256, vocab_size=512, head_dim=32,
        act="gelu", exit_layers=(2,),
        dtype=jnp.float32, param_dtype=jnp.float32,
        source="arXiv:2407.07726",
    )


def profile() -> HeteroProfile:
    return HeteroProfile(split_layers=(EXITS[0],) * 4 + (EXITS[1],) * 4
                         + (EXITS[2],) * 4)
