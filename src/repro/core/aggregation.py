"""Cross-layer aggregation — paper Eq. (1).

For every layer ``l`` of the full network, the participation set
``C_l = {i | l_i < l}`` (clients whose *server-side* model contains layer l)
averages its parameters; the mean is broadcast back to every member.  Models
are dicts keyed by layer name (``layer4``, ``head``, ...) so "common layers"
are identified by key across heterogeneous server models.

Three implementations:
  * ``cross_layer_aggregate``      — literal per-client loop (the reference,
    used by the paper-faithful Averaging strategy and by the test oracle).
  * ``masked_mean_over_axis``      — the SPMD collective form: a weighted
    ``psum`` over a mesh axis with per-layer participation masks, used by the
    production fused step (see core/spmd.py and docs/DESIGN.md §2).
  * ``stacked_cross_layer_aggregate`` — the in-graph form over
    cohort-stacked server models, traceable inside ``lax.scan``; the fused
    engine (repro.api.fused_engine) applies it under a ``lax.cond`` on the traced
    ``aggregate_every`` boundary predicate so aggregation never forces a
    host sync.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp


def _mean_trees(trees: Sequence[Any]) -> Any:
    n = float(len(trees))
    return jax.tree.map(lambda *xs: sum(x.astype(jnp.float32) for x in xs)
                        .astype(xs[0].dtype) / n, *trees)


def cross_layer_aggregate(server_models: Sequence[Dict[str, Any]],
                          split_layers: Sequence[int],
                          extra_shared_keys: Sequence[str] = ("head",),
                          ) -> List[Dict[str, Any]]:
    """Aggregate client-specific server models (Alg. 2 lines 20-30).

    server_models[i] is a dict whose keys are the layers client i's server
    model contains: ``layer{l}`` for l in (l_i, L] (1-indexed, paper naming)
    plus the keys in ``extra_shared_keys`` which every server model has.
    Returns NEW server models with common layers replaced by the mean.
    """
    assert len(server_models) == len(split_layers)
    out = [dict(m) for m in server_models]

    all_keys = set()
    for m in server_models:
        all_keys |= set(m.keys())

    for key in sorted(all_keys):
        members = [i for i, m in enumerate(server_models) if key in m]
        if len(members) <= 1:
            continue
        mean = _mean_trees([server_models[i][key] for i in members])
        for i in members:
            out[i][key] = mean
    return out


def stacked_cross_layer_aggregate(stacked: Dict[int, Dict[str, Any]],
                                  counts: Dict[int, int]
                                  ) -> Dict[int, Dict[str, Any]]:
    """Eq. (1) over cohort-stacked server models, inside the compiled graph.

    ``stacked[li]`` is the server model of the cohort with split layer ``li``,
    keyed by layer name, every leaf carrying a leading lane axis of size
    ``counts[li]`` (one lane per client).  For each layer key the mean is
    taken over *all* lanes of *all* cohorts containing that key — the same
    participation set C_l as :func:`cross_layer_aggregate` — and broadcast
    back to every member lane.  Keys held by a single client pass through
    unchanged.  Callers gate ``aggregate_every`` boundaries around this
    (e.g. ``lax.cond`` in repro.api.fused_engine) so no host round-trip is
    needed.  Under the spmd engine's recipe shardings the lane-dim
    ``jnp.sum`` is a reduce over the mesh's ``"lanes"`` axis and the
    broadcast re-materializes each lane's shard — XLA's partitioner emits
    the collectives; the math is identical to the single-device form.
    """
    out = {li: dict(m) for li, m in stacked.items()}
    all_keys = set()
    for m in stacked.values():
        all_keys |= set(m.keys())

    for key in sorted(all_keys):
        members = [li for li, m in stacked.items() if key in m]
        total = sum(counts[li] for li in members)
        if total <= 1:
            continue
        # lane-sum within each member cohort, then mean across cohorts
        sums = [jax.tree.map(lambda x: jnp.sum(x.astype(jnp.float32), axis=0),
                             stacked[li][key]) for li in members]
        mean = jax.tree.map(lambda *xs: sum(xs) / float(total), *sums)
        for li in members:
            out[li][key] = jax.tree.map(
                lambda old, m_: jnp.broadcast_to(
                    m_.astype(old.dtype)[None], old.shape),
                stacked[li][key], mean)
    return out


def participation_counts(split_layers: Sequence[int], num_layers: int):
    """For each 0-indexed layer l: (#clients with l client-side,
    #clients with l server-side).  Client i holds layers [0, l_i)."""
    n_client = [sum(1 for s in split_layers if l < s) for l in range(num_layers)]
    n_server = [len(split_layers) - c for c in n_client]
    return n_client, n_server


def masked_mean_over_axis(value: jnp.ndarray, participate: jnp.ndarray,
                          axis_name: str) -> jnp.ndarray:
    """SPMD Eq. (1): mean of ``value`` over the mesh axis restricted to
    shards where ``participate`` (0/1 scalar) is set.  The mean is broadcast
    back to the members of C_l only (paper Alg. 2 line 25); non-members keep
    their value unchanged."""
    num = jax.lax.psum(value * participate, axis_name)
    den = jax.lax.psum(participate, axis_name)
    mean = num / jnp.maximum(den, 1.0)
    return jnp.where((participate > 0) & (den > 0), mean, value)
