"""Segmented, scan-stacked decoder backbone with Hetero-SplitEE exit heads.

Layer layout
------------
``cfg.exit_layers`` partitions the ``num_layers`` blocks into *segments*.
After every segment boundary an **exit head** (the paper's client output
layer `f^(o)`) is attached.  Within a segment, layers are grouped into maximal
*runs* of identical (mixer, ffn) kind; every run of length > 1 is stacked
along a leading layer axis and driven by ``jax.lax.scan`` — this keeps the
HLO O(#runs) instead of O(#layers) (94-layer Qwen3-MoE compiles as a handful
of scans).  Layers of kind ``shared_attn`` (Zamba2's globally-shared
attention block) reference one top-level parameter set and are unrolled.

Hetero-SplitEE semantics (docs/DESIGN.md §2)
---------------------------------------
``split_ids`` assigns every example the *boundary index* of its client's cut
layer.  At boundary ``b`` the residual stream is replaced by
``stop_gradient`` for exactly the examples whose split is ``b``.  Hence for a
client with cut layer l_i:
  * its early-exit loss reaches layers 1..l_i (client-side training),
  * the final (server) loss reaches only layers l_i+1..L,
which is precisely Algorithm 1/2's gradient routing, fused into one SPMD
program.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import blocks as blocks_mod
from repro.models import frontend as frontend_mod
from repro.models import heads as heads_mod
from repro.models.common import embed, init_embedding, split_rng


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Run:
    mixer: str            # "attn" | "mla" | "mamba2" | "rwkv6" | "shared_attn"
    ffn: str
    start: int            # absolute layer index of the first layer in the run
    length: int

    @property
    def shared(self) -> bool:
        return self.mixer == "shared_attn"


def build_plan(cfg: ModelConfig) -> Tuple[Tuple[Run, ...], ...]:
    """Runs per segment."""
    plan: List[Tuple[Run, ...]] = []
    for (lo, hi) in cfg.segments():
        runs: List[Run] = []
        l = lo
        while l < hi:
            kind = (cfg.block_pattern[l], cfg.ffn_pattern[l])
            if cfg.block_pattern[l] == "shared_attn":
                runs.append(Run("shared_attn", cfg.ffn_pattern[l], l, 1))
                l += 1
                continue
            n = 1
            while (l + n < hi
                   and (cfg.block_pattern[l + n], cfg.ffn_pattern[l + n]) == kind
                   and cfg.block_pattern[l + n] != "shared_attn"):
                n += 1
            runs.append(Run(kind[0], kind[1], l, n))
            l += n
        plan.append(tuple(runs))
    return tuple(plan)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_backbone(rng, cfg: ModelConfig) -> dict:
    plan = build_plan(cfg)
    rngs = split_rng(rng, ["embed", "layers", "exits", "head", "shared", "front"])
    params: dict = {"embed": init_embedding(rngs["embed"], cfg.vocab_size,
                                            cfg.d_model, cfg.param_dtype)}

    if cfg.arch_type == "audio":
        params["frontend"] = frontend_mod.init_projector(
            rngs["front"], frontend_mod.WHISPER_FRAME_DIM, cfg)
    elif cfg.arch_type == "vlm":
        params["frontend"] = frontend_mod.init_projector(
            rngs["front"], frontend_mod.SIGLIP_PATCH_DIM, cfg)

    if any(r.shared for seg in plan for r in seg):
        params["shared_attn"] = blocks_mod.init_block(
            rngs["shared"], cfg, "attn", cfg.ffn_pattern[_first_shared(cfg)])

    seg_params: List[List[Any]] = []
    lrng = rngs["layers"]
    for seg in plan:
        run_params: List[Any] = []
        for run in seg:
            lrng, sub = jax.random.split(lrng)
            if run.shared:
                run_params.append({})        # references params["shared_attn"]
            elif run.length == 1:
                run_params.append(blocks_mod.init_block(sub, cfg, run.mixer, run.ffn))
            else:
                ks = jax.random.split(sub, run.length)
                run_params.append(jax.vmap(
                    lambda k: blocks_mod.init_block(k, cfg, run.mixer, run.ffn))(ks))
        seg_params.append(run_params)
    params["segments"] = seg_params

    n_exits = len(cfg.exit_layers)
    if n_exits:
        eks = jax.random.split(rngs["exits"], n_exits)
        params["exit_heads"] = [heads_mod.init_exit_head(k, cfg) for k in eks]
    params["head"] = heads_mod.init_lm_head(rngs["head"], cfg)
    return params


def _first_shared(cfg: ModelConfig) -> int:
    return next(i for i, b in enumerate(cfg.block_pattern) if b == "shared_attn")


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> list:
    """Cache pytree mirroring the plan: per segment, per run, a (stacked)
    block cache."""
    plan = build_plan(cfg)
    cache = []
    for seg in plan:
        seg_cache = []
        for run in seg:
            mixer = "attn" if run.shared else run.mixer
            one = blocks_mod.init_block_cache(cfg, mixer, run.ffn, batch,
                                              max_len, dtype)
            if run.length > 1:
                one = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (run.length, *a.shape)), one)
            seg_cache.append(one)
        cache.append(seg_cache)
    return cache


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


@dataclass
class BackboneOutput:
    logits: jnp.ndarray                       # final (server) logits
    exit_logits: Tuple[jnp.ndarray, ...]      # one per exit boundary
    aux_loss: jnp.ndarray                     # MoE load-balance etc.
    cache: Optional[list]                     # updated decode cache
    exit_features: Tuple[jnp.ndarray, ...]    # h_i at each boundary (pre-stop)


jax.tree_util.register_pytree_node(
    BackboneOutput,
    lambda o: ((o.logits, o.exit_logits, o.aux_loss, o.cache, o.exit_features), None),
    lambda _, c: BackboneOutput(*c),
)


def _run_forward(run: Run, run_params, shared_params, x, positions, cfg,
                 cache, cache_len, enc, remat: bool):
    """Apply one run (scan if stacked)."""
    mixer = "attn" if run.shared else run.mixer
    p = shared_params if run.shared else run_params

    body = functools.partial(blocks_mod.block_forward, cfg=cfg, mixer=mixer,
                             ffn=run.ffn)
    if remat:
        body = jax.checkpoint(body)

    if run.length == 1 or run.shared:
        x, new_c, aux = body(p, x, positions, cache=cache, cache_len=cache_len,
                             enc=enc)
        return x, new_c, aux

    def scan_body(carry, xs):
        h, aux_acc = carry
        layer_p, layer_c = xs
        h, new_c, aux = body(layer_p, h, positions, cache=layer_c,
                             cache_len=cache_len, enc=enc)
        return (h, aux_acc + aux), new_c

    init = (x, jnp.zeros((), jnp.float32))
    if cache is None:
        (x, aux), _ = jax.lax.scan(scan_body, init, (run_params, None),
                                   length=run.length)
        new_cache = None
    else:
        (x, aux), new_cache = jax.lax.scan(scan_body, init, (run_params, cache))
    return x, new_cache, aux


def backbone_forward(params: dict, cfg: ModelConfig, *,
                     tokens: Optional[jnp.ndarray] = None,
                     embeds: Optional[jnp.ndarray] = None,
                     enc: Optional[jnp.ndarray] = None,
                     split_ids: Optional[jnp.ndarray] = None,
                     cache: Optional[list] = None,
                     cache_len: Optional[jnp.ndarray] = None,
                     remat: bool = False) -> BackboneOutput:
    """Run the full network.

    tokens    : (B, T) int32, or None when ``embeds`` is given directly.
    embeds    : (B, S, feat) precomputed frontend embeddings (audio/vlm);
                concatenated *before* the token stream when both are given.
    enc       : (B, S, d_model) encoder states for cross-attention (audio).
    split_ids : (B,) int32 boundary index per example (Hetero-SplitEE); the
                residual stream is stop-gradient'ed at that boundary.  None
                disables split semantics (centralized model).
    cache     : decode cache from ``init_cache``; ``cache_len`` tokens filled.
    """
    plan = build_plan(cfg)
    if enc is not None and "frontend" in params:
        # stubbed encoder states -> d_model (audio carve-out projector)
        enc = frontend_mod.project(params["frontend"], enc).astype(cfg.dtype)
    parts = []
    if embeds is not None and "frontend" in params:
        parts.append(frontend_mod.project(params["frontend"], embeds))
    if tokens is not None:
        parts.append(embed(params["embed"], tokens).astype(cfg.dtype))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    x = x.astype(cfg.dtype)

    T = x.shape[1]
    if cache_len is not None:
        positions = cache_len + jnp.arange(T, dtype=jnp.int32)
    else:
        positions = jnp.arange(T, dtype=jnp.int32)

    aux_total = jnp.zeros((), jnp.float32)
    exit_logits: List[jnp.ndarray] = []
    exit_feats: List[jnp.ndarray] = []
    new_cache: Optional[list] = [] if cache is not None else None
    shared_p = params.get("shared_attn")

    n_seg = len(plan)
    for si, seg in enumerate(plan):
        for ri, run in enumerate(seg):
            run_c = cache[si][ri] if cache is not None else None
            x, run_c_new, aux = _run_forward(
                run, params["segments"][si][ri], shared_p, x, positions, cfg,
                run_c, cache_len, enc, remat)
            aux_total = aux_total + aux
            if cache is not None:
                new_cache.append((si, run_c_new))
        if si < n_seg - 1:
            # ---- Hetero-SplitEE boundary si ----
            exit_feats.append(x)
            exit_logits.append(
                heads_mod.exit_head(params["exit_heads"][si], x, cfg))
            if split_ids is not None:
                is_cut = (split_ids == si)[:, None, None]
                x = jnp.where(is_cut, jax.lax.stop_gradient(x), x)

    logits = heads_mod.lm_head(params["head"], x, cfg)
    if cache is not None:
        # regroup flat (si, cache) list back into per-segment lists
        regrouped: List[list] = [[] for _ in plan]
        for si, c in new_cache:
            regrouped[si].append(c)
        new_cache = regrouped
    return BackboneOutput(logits=logits, exit_logits=tuple(exit_logits),
                          aux_loss=aux_total, cache=new_cache,
                          exit_features=tuple(exit_feats))
