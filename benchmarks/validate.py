"""§Paper-validation: check the paper's qualitative claims against the
benchmark results (experiments/artifacts/bench_results.json) and emit the
markdown section for docs/EXPERIMENTS.md.

Claims validated (paper §IV):
  C1  Centralized is the upper bound everywhere (Tables III/IV).
  C2  Collaborative (Sequential/Averaging) beats Distributed on the hard
      task's server side, and the gap grows with task difficulty
      (syn100 gap > syn10 gap).
  C3  Sequential ≈ Averaging; closer in the heterogeneous setting.
  C4  Fig. 2: more conservative thresholds (fewer early exits) give higher
      accuracy and lower client adoption ratio — accuracy is monotone
      non-increasing in the exit ratio.
"""
from __future__ import annotations

import json
import sys
from collections import defaultdict

import numpy as np


def _get(rows, table, **kv):
    out = []
    for r in rows:
        if r.get("table") != table:
            continue
        if all(r.get(k) == v for k, v in kv.items()):
            out.append(r)
    return out


def _server(rows, method, dataset):
    vals = [r["server_acc"] for r in rows
            if r["method"] == method and r["dataset"] == dataset]
    return float(np.mean(vals)) if vals else float("nan")


def check(rows):
    checks = []

    for table in ("table3_homo", "table4_hetero"):
        trows = [r for r in rows if r.get("table") == table]
        if not trows:
            continue
        datasets = sorted({r["dataset"] for r in trows})
        # C1: centralized upper bound
        ok = True
        for ds in datasets:
            cent = _server(trows, "centralized", ds)
            others = [_server(trows, m, ds)
                      for m in ("sequential", "averaging", "distributed")]
            ok &= all(cent >= o - 1e-9 for o in others if o == o)
        checks.append((f"C1[{table}] centralized is the upper bound", ok))

        # C2: collaborative > distributed on the hard set; gap grows
        if "syn100" in datasets:
            gaps = {}
            for ds in datasets:
                collab = max(_server(trows, "sequential", ds),
                             _server(trows, "averaging", ds))
                gaps[ds] = collab - _server(trows, "distributed", ds)
            ok = gaps["syn100"] > 0
            checks.append((f"C2a[{table}] collaborative > distributed on "
                           f"syn100 (gap {gaps['syn100']:+.3f})", ok))
            if "syn10" in gaps:
                checks.append(
                    (f"C2b[{table}] gap grows with difficulty "
                     f"(syn100 {gaps['syn100']:+.3f} vs syn10 "
                     f"{gaps['syn10']:+.3f})", gaps["syn100"] >= gaps["syn10"]))

        # C3: sequential ~ averaging
        for ds in datasets:
            s = _server(trows, "sequential", ds)
            a = _server(trows, "averaging", ds)
            if s == s and a == a:
                checks.append((f"C3[{table}/{ds}] |seq-avg| = {abs(s-a):.3f} "
                               f"(small)", abs(s - a) < 0.08))

    # C4: threshold trade-off monotonicity (coarse, rank-correlation)
    frows = [r for r in rows if r.get("table") == "fig2_threshold"]
    if frows:
        by_layer = defaultdict(list)
        for r in frows:
            by_layer[r["layer"]].append((r["client_ratio"], r["acc"]))
        ok_all, corr_repr = True, 0.0
        for layer, pts in by_layer.items():
            pts.sort()
            ratios = [p[0] for p in pts]
            accs = [p[1] for p in pts]
            if len(set(ratios)) < 3:
                continue
            corr = np.corrcoef(ratios, accs)[0, 1]
            corr_repr = corr
            ok_all &= corr <= 0.05   # more exits should not increase accuracy
        checks.append((f"C4[fig2] accuracy non-increasing in exit ratio "
                       f"(corr {corr_repr:+.2f})", ok_all))
        # adoption ratio monotone in tau
        by_layer2 = defaultdict(list)
        for r in frows:
            by_layer2[r["layer"]].append((r["tau_entropy"], r["client_ratio"]))
        mono = all(all(b[1] >= a[1] - 1e-9 for a, b in
                       zip(sorted(p), sorted(p)[1:]))
                   for p in by_layer2.values())
        checks.append(("C4b[fig2] client adoption ratio monotone in tau",
                       mono))
    return checks


def markdown(rows):
    lines = ["\n## §Paper-validation\n",
             "Qualitative reproduction of the paper's claims on the "
             "synthetic CIFAR/STL stand-ins at reduced scale (see docs/DESIGN.md "
             "§7; orderings/gaps are the target, not absolute accuracies).\n"]
    # tables
    for table, title in (("table3_homo", "Table III (homogeneous clients)"),
                         ("table4_hetero", "Table IV (heterogeneous clients)")):
        trows = [r for r in rows if r.get("table") == table]
        if not trows:
            continue
        lines.append(f"\n### {title}\n")
        lines.append("| dataset | method | layer | server acc | client acc |")
        lines.append("|---|---|---|---|---|")
        for r in sorted(trows, key=lambda r: (r["dataset"], r["method"],
                                              r["layer"])):
            lines.append(f"| {r['dataset']} | {r['method']} | {r['layer']} | "
                         f"{r['server_acc']:.3f} | {r['client_acc']:.3f} |")
    frows = [r for r in rows if r.get("table") == "fig2_threshold"]
    if frows:
        lines.append("\n### Fig. 2 (threshold sensitivity, syn100, "
                     "Sequential)\n")
        lines.append("| layer | tau_entropy | tau_paper | acc | "
                     "client ratio |")
        lines.append("|---|---|---|---|---|")
        for r in sorted(frows, key=lambda r: (r["layer"], r["tau_entropy"])):
            lines.append(f"| {r['layer']} | {r['tau_entropy']:.2f} | "
                         f"{r['tau_paper']:.2f} | {r['acc']:.3f} | "
                         f"{r['client_ratio']:.3f} |")

    lines.append("\n### Claim checks\n")
    lines.append("| claim | holds |")
    lines.append("|---|---|")
    for name, ok in check(rows):
        lines.append(f"| {name} | {'✅' if ok else '❌'} |")
    return "\n".join(lines)


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else \
        "experiments/artifacts/bench_results.json"
    rows = json.load(open(path))
    print(markdown(rows))
