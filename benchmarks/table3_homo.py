"""Paper Table III: homogeneous client models.  12 clients, all at the same
end layer (3/4/5), x {Sequential, Averaging, Centralized, Distributed} x
{syn10, syn100, synstl}.  Emits one row per (method, location, dataset,
layer) cell."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import make_dataset, mean_by_depth, run_strategy

METHODS = ("sequential", "averaging", "centralized", "distributed")


def run(rounds: int = 40, train_size: int = 1200, test_size: int = 384,
        datasets=("syn10", "syn100"), layers=(3, 4, 5), n_clients: int = 6,
        seed: int = 0, engine: str = "auto") -> List[dict]:
    """``engine`` selects the TrainSession execution backend per cell
    ("auto" = fused where valid, reference for sequential/centralized)."""
    rows = []
    for ds_name in datasets:
        ds = make_dataset(ds_name, train_size, test_size, seed=seed)
        for layer in layers:
            splits = (layer,) * n_clients
            for method in METHODS:
                t0 = time.time()
                ev = run_strategy(ds, method,
                                  splits if method != "centralized"
                                  else (layer,) * n_clients,
                                  rounds=rounds, seed=seed, engine=engine)
                if method == "centralized":
                    client, server = ev["client_acc"][0], ev["server_acc"][0]
                else:
                    by = mean_by_depth(ev, splits)[layer]
                    client, server = by["client"], by["server"]
                rows.append({
                    "table": "table3_homo", "dataset": ds_name,
                    "method": method, "layer": layer,
                    "server_acc": round(server, 4),
                    "client_acc": round(client, 4),
                    "wall_s": round(time.time() - t0, 1),
                })
    return rows
