"""minitron-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000; width/depth-pruned Nemotron-4.  [arXiv:2407.14679]"""
from __future__ import annotations

from repro.config import HeteroProfile, ModelConfig

EXITS = (8, 16, 24)


def config(sliding_window=None) -> ModelConfig:
    return ModelConfig(
        name="minitron-8b", arch_type="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=16384, vocab_size=256000, head_dim=128,
        rope_theta=10000.0, act="silu", exit_layers=EXITS,
        sliding_window=sliding_window,
        source="arXiv:2407.14679",
    )


def smoke() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name="minitron-8b-smoke", arch_type="dense",
        num_layers=4, d_model=256, num_heads=8, num_kv_heads=2,
        d_ff=512, vocab_size=512, head_dim=32, exit_layers=(1, 2),
        dtype=jnp.float32, param_dtype=jnp.float32,
        source="arXiv:2407.14679",
    )


def profile() -> HeteroProfile:
    return HeteroProfile(split_layers=(EXITS[0],) * 4 + (EXITS[1],) * 4
                         + (EXITS[2],) * 4)
