"""The mesh-sharded spmd engine behind the TrainSession contract.

Two layers of coverage:

  * a **subprocess** harness that forces a 4-device host-CPU mesh
    (``XLA_FLAGS=--xla_force_host_platform_device_count=4``) so the
    acceptance equivalences always run under plain tier-1, even on a
    single-device container: spmd ``eq1`` ≡ reference across an
    ``aggregate_every=2`` boundary (params + per-round metrics ≤ 1e-4),
    spmd↔fused resume equivalence, a ``sum``-mode convergence smoke, and
    the periodic-save policy on the spmd engine;
  * **in-process** tests marked ``mesh`` that exercise the same engine
    directly when the test process already sees multiple devices (the
    tier-1 job line in .claude/skills/verify/SKILL.md runs them under the
    forced device count) and skip tier-1-safely otherwise.
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.api import TrainSession
from repro.config import HeteroProfile, OptimizerConfig, SplitEEConfig
from repro.core.splitee import MLPSplitModel

TOL = 1e-4          # float32 cross-device reduction-order tolerance

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="multi-device unavailable (tier-1-safe skip; run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _blob_parts(n_clients, n=600, d=16, classes=3, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(classes, d)) * 2.0
    y = rng.integers(0, classes, n).astype(np.int32)
    x = (centers[y] + rng.normal(size=(n, d))).astype(np.float32)
    return [(x[i::n_clients], y[i::n_clients]) for i in range(n_clients)]


def _session(engine, parts, splits=(1, 2, 2, 3), grad_mode="eq1",
             aggregate_every=2, mesh=None, recipe=None):
    model = MLPSplitModel(in_dim=16, hidden=32, num_classes=3, num_layers=4,
                          seed=0)
    return model, TrainSession.from_config(
        model,
        SplitEEConfig(profile=HeteroProfile(tuple(splits)),
                      strategy="averaging",
                      aggregate_every=aggregate_every),
        OptimizerConfig(lr=3e-3, total_steps=60),
        parts, batch_size=64, engine=engine, grad_mode=grad_mode, mesh=mesh,
        recipe=recipe)


def _max_state_delta(a, b):
    return max(float(np.max(np.abs(np.asarray(u, np.float64)
                                   - np.asarray(v, np.float64))))
               for u, v in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _max_metric_delta(a, b):
    assert len(a.history) == len(b.history)
    return max(max(abs(x.client_loss - y.client_loss),
                   abs(x.server_loss - y.server_loss))
               for x, y in zip(a.history, b.history))


# ---------------------------------------------------------------------------
# subprocess harness: always runs, forces the 4-device host mesh
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, tempfile
import numpy as np
import jax
from repro.api import TrainSession
from repro.config import HeteroProfile, OptimizerConfig, SplitEEConfig
from repro.core.splitee import MLPSplitModel

assert len(jax.devices()) == 4, jax.devices()
rng = np.random.default_rng(0)
centers = rng.normal(size=(3, 16)) * 2.0
y = rng.integers(0, 3, 600).astype(np.int32)
x = (centers[y] + rng.normal(size=(600, 16))).astype(np.float32)
model = MLPSplitModel(in_dim=16, hidden=32, num_classes=3, num_layers=4,
                      seed=0)
parts = [(x[i::4], y[i::4]) for i in range(4)]

def mk(engine, grad_mode="eq1"):
    return TrainSession.from_config(
        model,
        SplitEEConfig(profile=HeteroProfile((1, 2, 2, 3)),
                      strategy="averaging", aggregate_every=2),
        OptimizerConfig(lr=3e-3, total_steps=60), parts, batch_size=64,
        engine=engine, grad_mode=grad_mode)

def max_state_delta(a, b):
    return max(float(np.max(np.abs(np.asarray(u, np.float64)
                                   - np.asarray(v, np.float64))))
               for u, v in zip(jax.tree.leaves(a.state),
                               jax.tree.leaves(b.state)))

res = {}
res["auto_engine"] = mk("auto").engine_name

# --- spmd eq1 vs the reference oracle across an aggregation boundary ---
ref = mk("reference"); ref.train(4, local_epochs=2)
spmd = mk("spmd");     spmd.train(4, local_epochs=2)
res["param_delta"] = max_state_delta(ref, spmd)
res["metric_delta"] = max(
    max(abs(a.client_loss - b.client_loss),
        abs(a.server_loss - b.server_loss))
    for a, b in zip(ref.history, spmd.history))

# --- resume equivalence across engines: spmd -> save -> fused, and back ---
d = tempfile.mkdtemp()
half = mk("spmd"); half.train(2, local_epochs=2)
half.save(os.path.join(d, "ck"))
into_fused = TrainSession.restore(os.path.join(d, "ck"), model, parts,
                                  engine="fused")
into_fused.train(2, local_epochs=2)
res["resume_spmd_to_fused_delta"] = max_state_delta(ref, into_fused)

half2 = mk("fused"); half2.train(2, local_epochs=2)
half2.save(os.path.join(d, "ck2"))
into_spmd = TrainSession.restore(os.path.join(d, "ck2"), model, parts,
                                 engine="spmd")
into_spmd.train(2, local_epochs=2)
res["resume_fused_to_spmd_delta"] = max_state_delta(ref, into_spmd)

# --- sum-mode convergence smoke on the spmd engine ---
s = mk("spmd", grad_mode="sum")
ms = s.train(10)
res["sum_first"], res["sum_last"] = ms[0].server_loss, ms[-1].server_loss

# --- periodic save / restore_latest through the spmd engine ---
ckdir = os.path.join(d, "run")
p = mk("spmd"); p.train(5, save_every=2, save_dir=ckdir, keep_last=2)
res["ckpts"] = sorted(f for f in os.listdir(ckdir) if f.endswith(".json"))
res["latest_round"] = TrainSession.restore_latest(ckdir, model, parts).round

# --- lane + FSDP recipe on the (2,2,1) lanes/data/model host mesh ---
from repro.launch.mesh import make_lane_host_mesh
from repro.launch.shardings import ShardingRecipe

lane_mesh = make_lane_host_mesh(2)
fsdp = ShardingRecipe(min_shard_elems=2)     # force sharding of tiny leaves

def mk2(engine, mesh=None, recipe=None):
    # even cohorts (two clients per cut) so the 2-way lanes axis divides
    return TrainSession.from_config(
        model,
        SplitEEConfig(profile=HeteroProfile((1, 1, 2, 2)),
                      strategy="averaging", aggregate_every=2),
        OptimizerConfig(lr=3e-3, total_steps=60), parts, batch_size=64,
        engine=engine, mesh=mesh, recipe=recipe)

ref2 = mk2("reference"); ref2.train(4, local_epochs=2)
lane = mk2("spmd", mesh=lane_mesh, recipe=fsdp)
# params and Adam moments are ACTUALLY sharded: probe addressable shards
# of the engine-placed carry (cohort li=1, layer1 weight [E=2, 16, 32])
st = lane.state
carry = lane.engine._stack_carry(list(st.clients), list(st.client_opts),
                                 list(st.servers), list(st.server_opts))
w = carry[1][0]["trainable"]["layers"]["layer1"]["w"]
m = carry[1][1].m["layers"]["layer1"]["w"]
res["lane_w_global"] = list(w.shape)
res["lane_w_shard"] = list(w.addressable_shards[0].data.shape)
res["lane_m_shard"] = list(m.addressable_shards[0].data.shape)
lane.train(4, local_epochs=2)
res["lane_param_delta"] = max_state_delta(ref2, lane)
res["lane_metric_delta"] = max(
    max(abs(a.client_loss - b.client_loss),
        abs(a.server_loss - b.server_loss))
    for a, b in zip(ref2.history, lane.history))

# --- cross-recipe resume: lane+FSDP -> save -> "replicate" on the plain
# data mesh (and the saved custom recipe restores by default) ---
half3 = mk2("spmd", mesh=lane_mesh, recipe=fsdp)
half3.train(2, local_epochs=2)
half3.save(os.path.join(d, "ck3"))
same = TrainSession.restore(os.path.join(d, "ck3"), model, parts,
                            engine="spmd")
res["restored_recipe_min_elems"] = same.ctx.recipe.min_shard_elems
cross = TrainSession.restore(os.path.join(d, "ck3"), model, parts,
                             engine="spmd", recipe="replicate")
cross.train(2, local_epochs=2)
res["cross_recipe_resume_delta"] = max_state_delta(ref2, cross)

# --- staging pipeline on vs off on the spmd engine: bit-identical over
# chunk boundaries (chunk_rounds=3 puts an aggregate_every=2 boundary
# round first in chunk 2), plus a mid-run checkpoint resume ---
on = mk("spmd");  on.engine.overlap_staging = True
on.train(6, local_epochs=2, chunk_rounds=3)
off = mk("spmd"); off.engine.overlap_staging = False
off.train(6, local_epochs=2, chunk_rounds=3)
res["overlap_param_delta"] = max_state_delta(on, off)
res["overlap_metric_delta"] = max(
    max(abs(a.client_loss - b.client_loss),
        abs(a.server_loss - b.server_loss))
    for a, b in zip(on.history, off.history))
res["overlap_stats_on"] = on.engine.last_stage_stats
res["overlap_stats_off"] = off.engine.last_stage_stats

mid = mk("spmd"); mid.engine.overlap_staging = True
mid.train(3, local_epochs=2, chunk_rounds=2)
mid.save(os.path.join(d, "ck_ov"))
cont = TrainSession.restore(os.path.join(d, "ck_ov"), model, parts,
                            engine="spmd")
cont.engine.overlap_staging = True
cont.train(3, local_epochs=2, chunk_rounds=2)
res["overlap_resume_delta"] = max_state_delta(off, cont)

print(json.dumps(res))
"""


@pytest.fixture(scope="module")
def harness():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": os.environ.get("PATH", ""),
             "HOME": os.environ.get("HOME", "/tmp"),
             "JAX_PLATFORMS": "cpu"},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600)
    assert r.returncode == 0, r.stderr[-4000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_auto_selects_spmd_on_host_mesh(harness):
    assert harness["auto_engine"] == "spmd"


def test_spmd_eq1_matches_reference_on_host_mesh(harness):
    """Acceptance: spmd eq1 ≡ reference on a 4-device host mesh to ≤1e-4
    on params and per-round metrics, across an aggregate_every=2
    boundary."""
    assert harness["param_delta"] <= TOL, harness
    assert harness["metric_delta"] <= TOL, harness


def test_resume_equivalence_across_spmd_and_fused(harness):
    """A state saved mid-run by one engine continues the uninterrupted
    trajectory in the other, in both directions."""
    assert harness["resume_spmd_to_fused_delta"] <= TOL, harness
    assert harness["resume_fused_to_spmd_delta"] <= TOL, harness


def test_spmd_sum_mode_converges(harness):
    assert np.isfinite(harness["sum_last"])
    assert harness["sum_last"] < harness["sum_first"] * 0.7, harness


def test_spmd_periodic_save_policy(harness):
    """save_every=2/keep_last=2 over 5 rounds: checkpoints at rounds 2, 4,
    5, rotated to the newest two; restore_latest lands on round 5."""
    assert harness["ckpts"] == ["ckpt-00000004.json", "ckpt-00000005.json"]
    assert harness["latest_round"] == 5


def test_lane_fsdp_params_actually_shard(harness):
    """Acceptance: under a lane+FSDP recipe on the (2,2,1) host mesh, the
    cohort carry's params AND Adam moments are sharded, not replicated —
    asserted via addressable-shard shapes: lane dim 2 -> 1 on the lanes
    axis, the FSDP-picked dim halved on the data axis, moments mirroring
    their params exactly."""
    gw = harness["lane_w_global"]
    sw = harness["lane_w_shard"]
    assert gw == [2, 16, 32]
    assert sw[0] == 1                       # lane dim split over "lanes"
    assert int(np.prod(sw)) == int(np.prod(gw)) // 4   # 4-way total
    assert harness["lane_m_shard"] == sw    # moments mirror params


def test_lane_fsdp_matches_reference(harness):
    """Acceptance: spmd with the lane+FSDP recipe matches the reference
    engine to <= 1e-4 on params and per-round metrics across an
    aggregate_every=2 boundary."""
    assert harness["lane_param_delta"] <= TOL, harness
    assert harness["lane_metric_delta"] <= TOL, harness


def test_spmd_overlap_pipeline_bit_identical(harness):
    """The staging pipeline only reorders host work: the spmd trajectory
    with the double buffer on vs off is bit-identical across chunk
    boundaries (including the aggregate_every straddle), and a mid-run
    checkpoint resumed under the pipeline continues the serial
    trajectory."""
    assert harness["overlap_param_delta"] == 0.0, harness
    assert harness["overlap_metric_delta"] == 0.0, harness
    assert harness["overlap_stats_on"]["overlap"] is True
    assert harness["overlap_stats_on"]["chunks"] == 2
    assert harness["overlap_stats_off"]["overlap"] is False
    assert harness["overlap_stats_off"]["overlap_fraction"] == 0.0
    assert harness["overlap_resume_delta"] <= TOL, harness


def test_cross_recipe_resume(harness):
    """Acceptance: a state saved under the lane+FSDP recipe restores and
    continues under "replicate" on a plain data mesh (recipes are layout,
    not math), matching the uninterrupted reference run; restoring without
    an override brings the saved custom recipe back."""
    assert harness["restored_recipe_min_elems"] == 2
    assert harness["cross_recipe_resume_delta"] <= TOL, harness


# ---------------------------------------------------------------------------
# in-process mesh tests (the SKILL.md tier-1 mesh job; skip on one device)
# ---------------------------------------------------------------------------


@pytest.mark.mesh
@multi_device
def test_spmd_matches_reference_in_process():
    parts = _blob_parts(4)
    _, ref = _session("reference", parts)
    _, spmd = _session("spmd", parts)
    ref.train(3, local_epochs=2)
    spmd.train(3, local_epochs=2)
    assert _max_state_delta(ref.state, spmd.state) <= TOL
    assert _max_metric_delta(ref, spmd) <= TOL


@pytest.mark.mesh
@multi_device
def test_spmd_explicit_mesh_roundtrip():
    """An explicitly supplied mesh (the session's mesh= argument) is used
    and makes spmd eligible; chunked and single-chunk runs agree."""
    n = len(jax.devices())
    mesh = jax.make_mesh((n, 1), ("data", "model"))
    parts = _blob_parts(4, n=640)
    _, one = _session("spmd", parts, mesh=mesh)
    _, many = _session("spmd", parts, mesh=mesh)
    assert one.engine.mesh is mesh
    one.train(4)
    many.train(4, chunk_rounds=2)
    assert _max_state_delta(one.state, many.state) <= TOL


@pytest.mark.mesh
@multi_device
def test_lane_fsdp_matches_reference_in_process():
    """Lane+FSDP recipe on an in-process (2, n//2, 1) lanes mesh: the
    sharded run matches the reference trajectory, and the compiled carry
    shardings are non-trivial."""
    from repro.launch.mesh import make_lane_host_mesh
    from repro.launch.shardings import ShardingRecipe

    if len(jax.devices()) % 2:
        pytest.skip("needs an even device count for the lanes axis")
    mesh = make_lane_host_mesh(2)
    parts = _blob_parts(4)
    _, ref = _session("reference", parts, splits=(1, 1, 2, 2))
    _, lane = _session("spmd", parts, splits=(1, 1, 2, 2), mesh=mesh,
                       recipe=ShardingRecipe(min_shard_elems=2))
    specs = jax.tree.leaves(
        lane.engine._carry_specs,
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
    assert any("lanes" in s for s in specs if s)    # lanes axis in use
    ref.train(3, local_epochs=2)
    lane.train(3, local_epochs=2)
    assert _max_state_delta(ref.state, lane.state) <= TOL
    assert _max_metric_delta(ref, lane) <= TOL


@pytest.mark.mesh
@multi_device
def test_supports_rejects_wasted_lane_axis():
    """A lanes axis no cohort's lane count divides must fail at
    construction with an actionable diagnostic."""
    from repro.launch.mesh import make_lane_host_mesh

    n = len(jax.devices())
    if n < 4 or n % 4:
        pytest.skip("needs >= 4 devices for a 4-way lanes axis")
    mesh = make_lane_host_mesh(4)
    parts = _blob_parts(4)
    model = MLPSplitModel(in_dim=16, hidden=32, num_classes=3, num_layers=4,
                          seed=0)
    with pytest.raises(ValueError, match="lanes axis"):
        TrainSession.from_config(
            model,
            SplitEEConfig(profile=HeteroProfile((1, 2, 2, 3)),
                          strategy="averaging"),
            OptimizerConfig(total_steps=10), parts, batch_size=64,
            engine="spmd", mesh=mesh)


@pytest.mark.mesh
@multi_device
def test_spmd_rejects_indivisible_batch():
    """Effective batch sizes that do not divide over the data-parallel
    size must fail at construction with an actionable reason."""
    n_dev = len(jax.devices())
    parts = _blob_parts(2, n=600)
    model = MLPSplitModel(in_dim=16, hidden=32, num_classes=3, num_layers=4,
                          seed=0)
    bad = n_dev + 1 if (n_dev + 1) % n_dev else n_dev + 2
    with pytest.raises(ValueError, match="divide"):
        TrainSession.from_config(
            model,
            SplitEEConfig(profile=HeteroProfile((1, 2)),
                          strategy="averaging"),
            OptimizerConfig(total_steps=10), parts, batch_size=bad,
            engine="spmd")
