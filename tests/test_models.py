"""Backbone model behaviour: shapes, NaN-freedom, cache consistency, and
chunked-scan correctness against naive recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.backbone import (backbone_forward, build_plan, init_backbone,
                                   init_cache)
from repro.models.ssm import _mamba2_core_chunked, _wkv_chunked


def _roundtrip(cfg, T=8, extra=None):
    """full forward == prefill + 2 decode steps on the trailing tokens."""
    extra = extra or {}
    params = init_backbone(jax.random.PRNGKey(0), cfg)
    B = 2
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T + 2), 0,
                              cfg.vocab_size)
    full = backbone_forward(params, cfg, tokens=toks, **extra)
    cache = init_cache(cfg, B, 16, jnp.float32)
    pre = backbone_forward(params, cfg, tokens=toks[:, :T], cache=cache,
                           cache_len=jnp.zeros((), jnp.int32), **extra)
    d1 = backbone_forward(params, cfg, tokens=toks[:, T : T + 1],
                          cache=pre.cache,
                          cache_len=jnp.full((), T, jnp.int32), **extra)
    d2 = backbone_forward(params, cfg, tokens=toks[:, T + 1 :],
                          cache=d1.cache,
                          cache_len=jnp.full((), T + 1, jnp.int32), **extra)
    np.testing.assert_allclose(pre.logits, full.logits[:, :T], atol=2e-4)
    np.testing.assert_allclose(d1.logits[:, 0], full.logits[:, T], atol=2e-4)
    np.testing.assert_allclose(d2.logits[:, 0], full.logits[:, T + 1],
                               atol=2e-4)


def test_dense_forward_and_exits(tiny_dense):
    cfg = tiny_dense
    params = init_backbone(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0, cfg.vocab_size)
    out = backbone_forward(params, cfg, tokens=toks)
    assert out.logits.shape == (3, 8, cfg.vocab_size)
    assert len(out.exit_logits) == 2
    for e in out.exit_logits:
        assert e.shape == (3, 8, cfg.vocab_size)
        assert not bool(jnp.isnan(e).any())
    assert not bool(jnp.isnan(out.logits).any())


def test_plan_segments(tiny_dense):
    plan = build_plan(tiny_dense)
    assert len(plan) == 3                       # exits at 1,2 -> 3 segments
    assert sum(r.length for seg in plan for r in seg) == tiny_dense.num_layers


@pytest.mark.parametrize("fixture", ["tiny_dense", "tiny_swa", "tiny_mamba",
                                     "tiny_rwkv", "tiny_moe"])
def test_prefill_decode_consistency(fixture, request):
    _roundtrip(request.getfixturevalue(fixture))


def test_mamba2_chunked_vs_naive():
    rng = np.random.default_rng(0)
    B, T, H, P, S = 2, 12, 3, 4, 5
    xh = jnp.array(rng.normal(size=(B, T, H, P)), jnp.float32)
    Bm = jnp.array(rng.normal(size=(B, T, S)), jnp.float32)
    Cm = jnp.array(rng.normal(size=(B, T, S)), jnp.float32)
    dt = jnp.array(rng.uniform(0.1, 1.0, size=(B, T, H)), jnp.float32)
    A = -jnp.array(rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    D = jnp.array(rng.normal(size=(H,)), jnp.float32)
    log_a = dt * A

    h = np.zeros((B, H, P, S), np.float32)
    ys = []
    for t in range(T):
        a = np.exp(np.asarray(log_a)[:, t])
        u = np.asarray(xh)[:, t] * np.asarray(dt)[:, t, :, None]
        h = h * a[..., None, None] + np.einsum("bhp,bs->bhps", u,
                                               np.asarray(Bm)[:, t])
        y = (np.einsum("bhps,bs->bhp", h, np.asarray(Cm)[:, t])
             + np.asarray(D)[:, None] * np.asarray(xh)[:, t]
             * np.asarray(dt)[:, t, :, None])
        ys.append(y)
    ref = np.stack(ys, 1)

    for Q in (3, 4, 12):                        # incl. non-divisible padding
        y, hT = _mamba2_core_chunked(xh, Bm, Cm, log_a, dt, D, Q)
        np.testing.assert_allclose(np.asarray(y), ref, atol=2e-5)
        np.testing.assert_allclose(np.asarray(hT), h, atol=2e-5)


def test_wkv_chunked_vs_naive():
    rng = np.random.default_rng(1)
    B, T, H, K = 2, 10, 2, 4
    r = jnp.array(rng.normal(size=(B, T, H, K)), jnp.float32)
    k = jnp.array(rng.normal(size=(B, T, H, K)), jnp.float32)
    v = jnp.array(rng.normal(size=(B, T, H, K)), jnp.float32)
    log_w = -jnp.array(rng.uniform(0.05, 1.0, size=(B, T, H, K)), jnp.float32)
    u = jnp.array(rng.normal(size=(H, K)), jnp.float32)

    S = np.zeros((B, H, K, K), np.float32)
    ys = []
    for t in range(T):
        kt, vt, rt = (np.asarray(x)[:, t] for x in (k, v, r))
        wt = np.exp(np.asarray(log_w)[:, t])
        kv = np.einsum("bhk,bhv->bhkv", kt, vt)
        ys.append(np.einsum("bhk,bhkv->bhv", rt,
                            S + np.asarray(u)[None, :, :, None] * kv))
        S = S * wt[..., None] + kv
    ref = np.stack(ys, 1)

    for Q in (4, 5, 10):
        y, ST = _wkv_chunked(r, k, v, log_w, u, Q)
        np.testing.assert_allclose(np.asarray(y), ref, atol=2e-5)
        np.testing.assert_allclose(np.asarray(ST), S, atol=2e-5)


def test_split_stop_gradient_blocks_server_loss(tiny_dense):
    """The defining Hetero-SplitEE property: the server (final-head) loss has
    ZERO gradient w.r.t. client-side layers of each example's group, while
    exit losses reach exactly the layers at or below their cut."""
    cfg = tiny_dense
    params = init_backbone(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 6), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(2), (4, 6), 0,
                                cfg.vocab_size)
    # every example cut at boundary 0 (layer 1)
    split_ids = jnp.zeros((4,), jnp.int32)

    def server_loss(p):
        out = backbone_forward(p, cfg, tokens=toks, split_ids=split_ids)
        from repro.core.losses import softmax_cross_entropy
        return softmax_cross_entropy(out.logits, labels)

    g = jax.grad(server_loss)(params)
    # embedding + segment 0 (layer 0..1) must receive zero gradient
    emb_norm = sum(float(jnp.abs(x).sum())
                   for x in jax.tree.leaves(g["embed"]))
    seg0_norm = sum(float(jnp.abs(x).sum())
                    for x in jax.tree.leaves(g["segments"][0]))
    seg1_norm = sum(float(jnp.abs(x).sum())
                    for x in jax.tree.leaves(g["segments"][1]))
    assert emb_norm == 0.0
    assert seg0_norm == 0.0
    assert seg1_norm > 0.0                       # layers above the cut train
