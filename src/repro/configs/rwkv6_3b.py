"""rwkv6-3b [ssm] — Finch: 32L d_model=2560, attention-free RWKV6 time-mix
with data-dependent per-channel decay + channel-mix FFN d_ff=8960,
vocab=65536, head_dim=64 (40 heads).  [arXiv:2404.05892]"""
from __future__ import annotations

from repro.config import HeteroProfile, ModelConfig, SSMConfig

NUM_LAYERS = 32
EXITS = (8, 16, 24)


def config(sliding_window=None) -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", arch_type="ssm",
        num_layers=NUM_LAYERS, d_model=2560, num_heads=40, num_kv_heads=40,
        d_ff=8960, vocab_size=65536, head_dim=64,
        block_pattern=("rwkv6",) * NUM_LAYERS,
        ffn_pattern=("rwkv_cm",) * NUM_LAYERS,
        ssm=SSMConfig(kind="rwkv6", head_dim=64, chunk_size=128),
        exit_layers=EXITS, sliding_window=sliding_window,
        source="arXiv:2404.05892",
    )


def smoke() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name="rwkv6-3b-smoke", arch_type="ssm",
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512, head_dim=32,
        block_pattern=("rwkv6",) * 4, ffn_pattern=("rwkv_cm",) * 4,
        ssm=SSMConfig(kind="rwkv6", head_dim=32, chunk_size=8),
        exit_layers=(2,), dtype=jnp.float32, param_dtype=jnp.float32,
        source="arXiv:2404.05892",
    )


def profile() -> HeteroProfile:
    return HeteroProfile(split_layers=(EXITS[0],) * 4 + (EXITS[1],) * 4
                         + (EXITS[2],) * 4)
