"""Optional in-model activation sharding constraints (MaxText-style).

``launch``-layer step builders activate the context with the mesh's axis
sizes; model code then pins hot intermediate activations (e.g. the MoE
dispatch buffers) with ``lax.with_sharding_constraint``.  When the context is
inactive (unit tests, single-device runs) every call is a no-op, so model
code stays mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _sizes() -> Optional[Dict[str, int]]:
    return getattr(_state, "sizes", None)


@contextlib.contextmanager
def activation_sharding(mesh):
    """Enable activation constraints for the given mesh (axis name -> size)."""
    prev = _sizes()
    _state.sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    try:
        yield
    finally:
        _state.sizes = prev


def constrain(x, *dim_axes):
    """Constrain ``x`` so dim i is sharded over ``dim_axes[i]``: a mesh axis
    name, a tuple of names, None, or a LIST of such candidates (first one
    whose size exists and divides the dim wins).  No-op outside an
    ``activation_sharding`` context.  Each mesh axis is used at most once."""
    sizes = _sizes()
    if sizes is None:
        return x
    spec = []
    used: set = set()

    def fits(ax, dim):
        axes = ax if isinstance(ax, tuple) else (ax,)
        if any(a not in sizes or a in used for a in axes):
            return False
        n = 1
        for a in axes:
            n *= sizes[a]
        return n > 1 and dim % n == 0

    for i, cand in enumerate(dim_axes):
        cands = cand if isinstance(cand, list) else [cand]
        chosen = None
        for ax in cands:
            if ax is None:
                continue
            if fits(ax, x.shape[i]):
                chosen = ax
                break
        spec.append(chosen)
        if chosen is not None:
            used.update(chosen if isinstance(chosen, tuple) else (chosen,))
    return jax.lax.with_sharding_constraint(x, P(*spec))
