"""Smoke-test the engine benchmark end-to-end at CI size: two tiny rounds
per engine, then validate the emitted ``BENCH_fused.json`` and
``BENCH_spmd.json`` schemas so the benchmark can't silently rot."""
import json
import os

import jax
import pytest

from benchmarks import fused_vs_reference


@pytest.fixture(scope="module")
def bench_artifacts(tmp_path_factory):
    """One tiny benchmark run shared by the schema tests."""
    d = tmp_path_factory.mktemp("bench")
    out = os.path.join(d, "BENCH_fused.json")
    spmd_out = os.path.join(d, "BENCH_spmd.json")
    rows = fused_vs_reference.run(rounds=2, clients=4, batch_size=32,
                                  out=out, spmd_out=spmd_out)
    return rows, out, spmd_out


def test_fused_benchmark_emits_valid_json(bench_artifacts):
    rows, out, _ = bench_artifacts

    # rows consumable by benchmarks/run.py's CSV emitter; the spmd row is
    # present exactly when the engine supported this host (it may reject a
    # multi-device host too, e.g. when the batch doesn't divide the mesh)
    assert len(rows) in (2, 3)
    if len(jax.devices()) == 1:
        assert len(rows) == 2               # spmd needs a mesh
    for r in rows:
        assert set(("name", "us_per_call", "derived")) <= set(r)

    with open(out) as f:
        data = json.load(f)
    assert set(fused_vs_reference.SCHEMA_KEYS) <= set(data)
    assert data["benchmark"] == "fused_vs_reference"
    assert data["config"]["clients"] == 4
    assert len(data["config"]["splits"]) == 4
    for eng in ("reference", "fused"):
        assert data[eng]["wall_s"] > 0
        assert data[eng]["rounds_per_sec"] > 0
    assert data["speedup"] == pytest.approx(
        data["reference"]["wall_s"] / data["fused"]["wall_s"])
    # engines trained on identical minibatches: metrics must agree
    assert data["max_metric_delta"] < 1e-4


def test_spmd_benchmark_manifest_records_execution_path(bench_artifacts):
    """The three-way manifest must always say what actually ran: real
    timings (with the engine_path note) on a multi-device host, or an
    explicit skip reason on a single-device one — never a silent absence."""
    _, _, spmd_out = bench_artifacts
    with open(spmd_out) as f:
        data = json.load(f)
    assert set(fused_vs_reference.SPMD_SCHEMA_KEYS) <= set(data)
    assert data["benchmark"] == "spmd_vs_fused_vs_reference"
    assert data["config"]["devices"] == len(jax.devices())
    assert data["speedup"]["fused"] > 0
    # the leg is real-or-skip-reason, keyed on what actually ran (a
    # multi-device host can still skip, e.g. batch not dividing the mesh)
    if "skipped" in data["spmd"]:
        assert data["spmd"]["skipped"]          # non-empty reason
        assert data["speedup"]["spmd"] is None
        if len(jax.devices()) == 1:
            assert "device" in data["spmd"]["skipped"]
    else:
        assert data["spmd"]["wall_s"] > 0
        assert data["max_metric_delta"]["spmd"] < 1e-4
        assert data["spmd"]["engine_path"] == "spmd"
    if len(jax.devices()) == 1:
        assert "skipped" in data["spmd"]
