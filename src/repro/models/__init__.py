from repro.models import backbone, resnet  # noqa: F401
