"""The paper's own experimental model: Table-I ResNet-18 with end_layer
splits on CIFAR-10/100 and STL-10 shapes (synthetic stand-in datasets in
this offline container; see data/synthetic.py)."""
from __future__ import annotations

from repro.config import HeteroProfile
from repro.models.resnet import ResNetConfig

# paper heterogeneous setting: 12 clients, 4 each at end layers 3/4/5
HETERO_SPLITS = (3,) * 4 + (4,) * 4 + (5,) * 4


def config(dataset: str = "cifar10", width_mult: float = 1.0) -> ResNetConfig:
    num_classes = {"cifar10": 10, "cifar100": 100, "stl10": 10}[dataset]
    stem_stride = 2 if dataset == "stl10" else 1
    image_size = 96 if dataset == "stl10" else 32
    return ResNetConfig(num_classes=num_classes, stem_stride=stem_stride,
                        width_mult=width_mult, image_size=image_size)


def smoke() -> ResNetConfig:
    return ResNetConfig(num_classes=10, width_mult=0.125, image_size=32)


def profile(homo_layer: int | None = None) -> HeteroProfile:
    if homo_layer is not None:
        return HeteroProfile(split_layers=(homo_layer,) * 12)
    return HeteroProfile(split_layers=HETERO_SPLITS)
