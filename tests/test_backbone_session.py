"""The production backbones through TrainSession: ``BackboneSplitModel``
(core/backbone_splitee.py) + the ``--arch`` CLI.

Coverage (the PR's acceptance gates):

  * protocol conformance of the adapter, client/server partition shapes;
  * fused ≡ reference to <= 1e-4 on a dense (glm4) and a MoE (qwen3)
    smoke config, including an ``aggregate_every=2`` boundary;
  * cross-engine resume round-trip (train 2k ≡ train k/save/restore/k,
    fused -> reference hand-off) with the arch name in the manifest;
  * restore into a *different* architecture refuses loudly;
  * the ``--arch``/``--smoke`` CLI end to end via subprocess: trains,
    writes a manifest + driver sidecar recording the arch, resumes, and
    fails loudly on arch / grad-mode mismatches;
  * the NaN-gradient regression in the mamba2 backward (the where-grad
    trap on non-causal exp overflow) stays fixed: a zamba2 smoke step
    keeps every parameter finite.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro import configs as configs_mod
from repro.api import TrainSession
from repro.api.protocol import assert_split_model
from repro.config import HeteroProfile, OptimizerConfig, SplitEEConfig
from repro.core.backbone_splitee import BackboneSplitModel
from repro.data.pipeline import ClientPartitioner
from repro.data.synthetic import SyntheticSeqClsDataset

TOL = 1e-4
#: the spmd leg pays float32 cross-device reduction-order noise per layer
#: per round; the 4-layer transformer accumulates more of it than the MLP
#: harness in test_spmd_engine.py, so its bound is looser (still far below
#: any training-relevant scale)
SPMD_TOL = 1e-3


def _parts(cfg, n_clients, seed=0, train_size=128):
    ds = SyntheticSeqClsDataset(vocab_size=cfg.vocab_size, seq_len=8,
                                num_classes=8, train_size=train_size,
                                test_size=64, seed=seed)
    return ClientPartitioner(n_clients, seed=seed).split(*ds.train), ds.test


def _session(model, parts, splits, engine, aggregate_every=1, lr=1e-3):
    return TrainSession.from_config(
        model,
        SplitEEConfig(profile=HeteroProfile(tuple(splits)),
                      strategy="averaging",
                      aggregate_every=aggregate_every),
        OptimizerConfig(lr=lr, total_steps=64),
        parts, batch_size=16, engine=engine)


def _max_state_delta(a, b):
    return max(float(np.max(np.abs(np.asarray(u, np.float64)
                                   - np.asarray(v, np.float64))))
               for u, v in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _metric_delta(ha, hb):
    return max(max(abs(a.client_loss - b.client_loss),
                   abs(a.server_loss - b.server_loss))
               for a, b in zip(ha, hb))


@pytest.fixture(scope="module")
def glm4():
    cfg = configs_mod.get("glm4_9b").smoke()
    return BackboneSplitModel(cfg, seed=0)


# ---------------------------------------------------------------- protocol


def test_protocol_conformance(glm4):
    assert_split_model(glm4)                     # no raise
    assert glm4.cut_layers == (1, 2)
    assert glm4.name == "glm4-9b-smoke"


def test_partition_layout(glm4):
    # cut at boundary 0: client = segment 0 + exit head, server = seg1, seg2
    c = glm4.make_client(1)
    s = glm4.make_server(1)
    assert set(c["trainable"]) == {"embed", "segments", "out"}
    assert len(c["trainable"]["segments"]) == 1
    assert set(s["trainable"]) == {"seg1", "seg2", "head"}
    # deeper cut: more client segments, fewer server keys
    c2, s2 = glm4.make_client(2), glm4.make_server(2)
    assert len(c2["trainable"]["segments"]) == 2
    assert set(s2["trainable"]) == {"seg2", "head"}
    # Eq. (1): the deep server's keys are a subset of the shallow server's,
    # so common trunks are matched by name across heterogeneous depths
    assert set(s2["trainable"]) < set(s["trainable"])


def test_invalid_cut_layer(glm4):
    with pytest.raises(ValueError, match="not an exit boundary"):
        glm4.make_client(3)


def test_needs_exit_layers():
    cfg = configs_mod.get("glm4_9b").smoke().with_(exit_layers=())
    with pytest.raises(ValueError, match="exit_layers"):
        BackboneSplitModel(cfg)


# ------------------------------------------------------------- equivalence


def test_fused_equals_reference_glm4(glm4):
    parts, _ = _parts(glm4.cfg, 4)
    splits = (1, 1, 2, 2)
    ref = _session(glm4, parts, splits, "reference", aggregate_every=2)
    ref.train(4)
    fus = _session(glm4, parts, splits, "fused", aggregate_every=2)
    fus.train(4)
    assert _metric_delta(ref.history, fus.history) <= TOL
    assert _max_state_delta(ref.state, fus.state) <= TOL


def test_fused_equals_reference_qwen3_moe():
    cfg = configs_mod.get("qwen3_moe_235b_a22b").smoke()
    model = BackboneSplitModel(cfg, seed=0)
    parts, _ = _parts(cfg, 2)
    splits = (2, 2)
    ref = _session(model, parts, splits, "reference")
    ref.train(3)
    fus = _session(model, parts, splits, "fused")
    fus.train(3)
    assert _metric_delta(ref.history, fus.history) <= TOL
    assert _max_state_delta(ref.state, fus.state) <= TOL


def test_moe_aux_loss_routes_through_split_losses():
    """The MoE load-balance aux loss reaches training through the
    client_loss/server_loss hooks, split by family: each side's loss is its
    CE plus its own segments' config-weighted aux total (nonzero on the MoE
    smoke config, exactly zero on a dense one) — and the engines stay
    equivalent with it in the graph (the qwen3 test above trains through
    the same hooks)."""
    from repro.core.losses import softmax_cross_entropy

    cfg = configs_mod.get("qwen3_moe_235b_a22b").smoke()
    model = BackboneSplitModel(cfg, seed=0)
    parts, _ = _parts(cfg, 2, train_size=64)
    x, y = parts[0][0][:8], parts[0][1][:8]

    c = model.make_client(2)
    h, logits, _ = model.client_forward(c["trainable"], c["state"], x,
                                        train=True)
    ce = float(softmax_cross_entropy(logits, y))
    loss, (h2, _) = model.client_loss(c["trainable"], c["state"], x, y)
    aux_c = float(loss) - ce
    assert aux_c > 0, "client-side MoE segments must contribute aux"
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h))

    s = model.make_server(2)
    slogits, _ = model.server_forward(s["trainable"], s["state"], h, 2,
                                      train=True)
    sce = float(softmax_cross_entropy(slogits, y))
    sloss, _ = model.server_loss(s["trainable"], s["state"], h, 2, y)
    assert float(sloss) - sce > 0, "server-side segments must contribute aux"

    # the weight knob actually scales it (weighted per the config)
    heavy = BackboneSplitModel(
        cfg.with_(moe=dataclasses.replace(cfg.moe,
                                          router_aux_weight=10 * cfg.moe
                                          .router_aux_weight)), seed=0)
    hc = heavy.make_client(2)
    hloss, _ = heavy.client_loss(hc["trainable"], hc["state"], x, y)
    np.testing.assert_allclose(float(hloss) - ce, 10 * aux_c, rtol=1e-4)

    # dense configs pay exactly nothing through the same hooks
    dense = configs_mod.get("glm4_9b").smoke()
    dmodel = BackboneSplitModel(dense, seed=0)
    dparts, _ = _parts(dense, 2, train_size=64)
    dx, dy = dparts[0][0][:8], dparts[0][1][:8]
    dc = dmodel.make_client(2)
    _, dlogits, _ = dmodel.client_forward(dc["trainable"], dc["state"], dx,
                                          train=True)
    dloss, _ = dmodel.client_loss(dc["trainable"], dc["state"], dx, dy)
    assert float(dloss) == pytest.approx(
        float(softmax_cross_entropy(dlogits, dy)), abs=0)


def test_mamba2_backward_stays_finite():
    """Regression: exp overflow on non-causal segment-sum entries used to
    poison the mamba2 VJP (inf * 0 = NaN through the where), blowing up
    every parameter after one Adam step."""
    cfg = configs_mod.get("zamba2_1p2b").smoke()
    model = BackboneSplitModel(cfg, seed=0)
    parts, _ = _parts(cfg, 2, train_size=64)
    sess = _session(model, parts, (2, 2), "reference")
    sess.train(2)
    assert all(np.isfinite([m.client_loss, m.server_loss])
               .all() for m in sess.history)
    assert all(bool(np.isfinite(np.asarray(leaf, np.float32)).all())
               for leaf in jax.tree.leaves(sess.state))


# ------------------------------------------------------------------ resume


def test_cross_engine_resume_roundtrip(glm4, tmp_path):
    parts, test = _parts(glm4.cfg, 4)
    splits = (1, 1, 2, 2)
    ref = _session(glm4, parts, splits, "fused", aggregate_every=2)
    ref.train(4)

    half = _session(glm4, parts, splits, "fused", aggregate_every=2)
    half.train(2)
    path = str(tmp_path / "ckpt")
    half.save(path)
    with open(path + ".json") as f:
        meta = json.load(f)["metadata"]
    assert meta["model"] == "glm4-9b-smoke"      # arch recorded

    # hand the state to the OTHER engine and finish the run
    resumed = TrainSession.restore(path, glm4, parts, engine="reference")
    assert resumed.round == 2
    resumed.train(2)
    assert _max_state_delta(ref.state, resumed.state) <= TOL
    assert _metric_delta(ref.history, resumed.history) <= TOL

    # evaluation runs on the restored state
    ev = resumed.evaluate(*test, batch_size=32)
    assert len(ev["client_acc"]) == 4


def test_restore_refuses_other_arch(glm4, tmp_path):
    parts, _ = _parts(glm4.cfg, 2, train_size=64)
    sess = _session(glm4, parts, (1, 2), "reference")
    sess.train(1)
    path = str(tmp_path / "ckpt")
    sess.save(path)

    other = BackboneSplitModel(configs_mod.get("qwen3_moe_235b_a22b").smoke())
    with pytest.raises(ValueError, match="different architecture"):
        TrainSession.restore(path, other, parts)


# -------------------------------------------------------------------- spmd

SPMD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax
from repro import configs as configs_mod
from repro.api import TrainSession
from repro.config import HeteroProfile, OptimizerConfig, SplitEEConfig
from repro.core.backbone_splitee import BackboneSplitModel
from repro.data.pipeline import ClientPartitioner
from repro.data.synthetic import SyntheticSeqClsDataset

assert len(jax.devices()) == 4, jax.devices()
cfg = configs_mod.get("glm4_9b").smoke()
model = BackboneSplitModel(cfg, seed=0)
ds = SyntheticSeqClsDataset(vocab_size=cfg.vocab_size, seq_len=8,
                            num_classes=8, train_size=128, test_size=32)
parts = ClientPartitioner(4, seed=0).split(*ds.train)

def mk(engine):
    return TrainSession.from_config(
        model,
        SplitEEConfig(profile=HeteroProfile((1, 1, 2, 2)),
                      strategy="averaging", aggregate_every=2),
        OptimizerConfig(lr=1e-3, total_steps=32), parts, batch_size=16,
        engine=engine)

ref = mk("reference"); ref.train(3)
spmd = mk("spmd");     spmd.train(3)
delta = max(float(np.max(np.abs(np.asarray(u, np.float64)
                                - np.asarray(v, np.float64))))
            for u, v in zip(jax.tree.leaves(ref.state),
                            jax.tree.leaves(spmd.state)))
print(json.dumps({"engine": spmd.engine_name, "param_delta": delta}))
"""


def test_spmd_engine_runs_backbone():
    """The backbone adapter needs no spmd-specific code: the mesh engine
    stages the identical cohort step, matching the reference to SPMD_TOL
    on a 4-device host mesh (subprocess so tier-1 stays single-device)."""
    r = subprocess.run(
        [sys.executable, "-c", SPMD_SCRIPT], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": os.environ.get("PATH", ""),
             "HOME": os.environ.get("HOME", "/tmp"),
             "JAX_PLATFORMS": "cpu"},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600)
    assert r.returncode == 0, r.stderr[-4000:]
    res = json.loads(r.stdout.strip().splitlines()[-1])
    assert res["engine"] == "spmd"
    assert res["param_delta"] <= SPMD_TOL


# --------------------------------------------------------------------- CLI


_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_cli(*extra, ckpt_dir, arch="glm4_9b"):
    args = [sys.executable, "-m", "repro.launch.train",
            "--arch", arch, "--smoke", "--clients", "2",
            "--batch", "16", "--seq-len", "8", "--train-size", "64",
            "--test-size", "32", "--checkpoint-dir", str(ckpt_dir),
            *extra]
    return subprocess.run(
        args, capture_output=True, text=True, cwd=_REPO_ROOT, timeout=600,
        env={"PYTHONPATH": "src", "PATH": os.environ.get("PATH", ""),
             "HOME": os.environ.get("HOME", "/tmp"),
             "JAX_PLATFORMS": "cpu"})


def test_arch_cli_train_resume_and_mismatches(tmp_path):
    ckpt = tmp_path / "run"

    r = _run_cli("--engine", "reference", "--rounds", "2", ckpt_dir=ckpt)
    assert r.returncode == 0, r.stderr
    assert "arch=glm4_9b (smoke) [glm4-9b-smoke]" in r.stdout
    manifests = sorted(ckpt.glob("ckpt-*.json"))
    assert manifests, r.stdout
    with open(manifests[-1]) as f:
        assert json.load(f)["metadata"]["model"] == "glm4-9b-smoke"
    with open(ckpt / "driver.json") as f:
        sidecar = json.load(f)
    assert sidecar["arch"] == "glm4_9b" and sidecar["smoke"] is True

    # resume onto the fused engine: trains only the remainder
    r = _run_cli("--engine", "fused", "--rounds", "3", "--resume",
                 ckpt_dir=ckpt)
    assert r.returncode == 0, r.stderr
    assert "[resumed at round 2]" in r.stdout

    # arch mismatch dies loudly before touching the checkpoints
    bad = _run_cli("--engine", "reference", "--rounds", "5", "--resume",
                   ckpt_dir=ckpt, arch="qwen3_moe_235b_a22b")
    assert bad.returncode != 0
    assert "--resume mismatch" in bad.stderr and "--arch" in bad.stderr

    # grad-mode mismatch dies loudly too
    bad = _run_cli("--engine", "fused", "--grad-mode", "sum", "--rounds",
                   "5", "--resume", ckpt_dir=ckpt)
    assert bad.returncode != 0
    assert "--resume mismatch" in bad.stderr and "--grad-mode" in bad.stderr

    # unknown arch: a clear error, not a traceback
    r = _run_cli("--engine", "reference", "--rounds", "1",
                 ckpt_dir=tmp_path / "x", arch="not_an_arch")
    assert r.returncode != 0
    assert "not a registered architecture" in r.stderr
