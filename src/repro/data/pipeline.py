"""Host-side data pipeline: IID client partitioning (paper §IV-A) and batch
iterators, including the group-contiguous global-batch assembly used by the
fused SPMD Hetero-SplitEE step (client group g owns slice g of the batch)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class ClientPartitioner:
    """Uniform-at-random IID split of (x, y) across N clients.  The same
    partition (same seed) is reused by every strategy/baseline so that
    'observed performance differences isolate the effect of collaborative
    aggregation' (paper §IV-A4)."""

    num_clients: int
    seed: int = 0

    def split(self, x: np.ndarray, y: np.ndarray
              ) -> List[Tuple[np.ndarray, np.ndarray]]:
        rng = np.random.default_rng(self.seed)
        perm = rng.permutation(len(x))
        shards = np.array_split(perm, self.num_clients)
        return [(x[s], y[s]) for s in shards]


def effective_batch_size(n: int, batch_size: int) -> int:
    """The batch size :func:`batch_iterator` actually emits for a shard of
    ``n`` samples: tiny client shards fall back to full-shard batches.  The
    single source of truth for every consumer (the fused engine validates
    cohort stackability against this)."""
    return min(batch_size, n)


def batch_iterator(x: np.ndarray, y: np.ndarray, batch_size: int, *,
                   seed: int = 0, augment=None, epochs: int = 1_000_000
                   ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    n = len(x)
    bs = effective_batch_size(n, batch_size)
    for _ in range(epochs):
        perm = rng.permutation(n)
        for i in range(0, n - bs + 1, bs):
            idx = perm[i : i + bs]
            bx = x[idx]
            if augment is not None:
                bx = augment(rng, bx)
            yield bx, y[idx]


def prestage_batches(it: Iterator[Tuple[np.ndarray, np.ndarray]],
                     rounds: int, local_epochs: int,
                     out: Optional[Tuple[np.ndarray, np.ndarray]] = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Draw ``rounds * local_epochs`` consecutive batches from a
    :func:`batch_iterator` into ``[rounds, local_epochs, B, ...]`` host
    tensors, ready to be device-put once and scanned over.  Consuming the
    *same* iterator the reference engine would consume keeps the minibatch
    sequence bit-identical between engines (the equivalence contract in
    docs/ENGINES.md).

    Each drawn batch is written straight into its slot — one host copy per
    batch, instead of the list + ``np.stack`` + ``reshape`` path that held
    two full extra copies of every chunk.  ``out=(bx, by)`` fills
    caller-owned buffers in place (the engines pass views into the
    preallocated cohort-stacked chunk, eliminating the lane-stacking copy
    as well); buffers may be non-contiguous views but must have the
    ``[rounds, local_epochs, ...batch shape]`` leading layout."""
    bx = by = None
    if out is not None:
        bx, by = out
    for r in range(rounds):
        for e in range(local_epochs):
            x, y = next(it)
            if bx is None:
                bx = np.empty((rounds, local_epochs, *x.shape), x.dtype)
                by = np.empty((rounds, local_epochs, *y.shape), y.dtype)
            bx[r, e] = x
            by[r, e] = y
    return bx, by


def global_hetero_batch(client_batches: Sequence[Tuple[np.ndarray, np.ndarray]],
                        split_boundary_ids: Sequence[int]
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Assemble the fused-SPMD global batch: concatenate per-client batches in
    group order and emit the per-example split-boundary id vector."""
    xs = np.concatenate([b[0] for b in client_batches], axis=0)
    ys = np.concatenate([b[1] for b in client_batches], axis=0)
    ids = np.concatenate([
        np.full((len(b[0]),), sid, np.int32)
        for b, sid in zip(client_batches, split_boundary_ids)
    ])
    return xs, ys, ids
