# Hetero-SplitEE core: the paper's contribution as composable JAX modules.
#   splitee.py      — split specs, per-client model partitioning (the
#                     repro.api.protocol.SplitModel adapters)
#   losses.py       — CE / entropy / confidence
#   aggregation.py  — Eq. (1) cross-layer aggregation
#   strategies.py   — shared client/server step builders + HeteroTrainer shim
#   fused.py        — FusedHeteroTrainer shim (engines live in repro.api)
#   spmd.py         — fused SPMD production train step (masked exits + routing)
#   inference.py    — Alg. 3 entropy-gated adaptive inference
#
# Training engines and the TrainSession facade live in repro.api
# (docs/API.md); the trainer classes here are deprecation shims.
