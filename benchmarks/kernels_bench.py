"""Kernel parity + latency bench, micro AND model-layer.

On this CPU container the Pallas kernels run in interpret mode, so
wall-times are NOT TPU estimates — the benchmark's purpose is (a) parity
vs the jnp oracle on bench-scale shapes, (b) a regression guard on call
overhead, and (c) the **dispatch leg**: the full model layer run end to end
under ``kernels="pallas"`` vs ``kernels="ref"`` (``repro.kernels.dispatch``
routes the GQA contraction, the RWKV6 wkv recurrence, and the serve-step
entropy gate), with the pallas/ref deltas gated by ``--max-delta`` — the
``kernels-smoke`` CI job.

  PYTHONPATH=src python -m benchmarks.kernels_bench --max-delta 1e-3

writes ``BENCH_kernels.json`` and exits non-zero when any routed site
diverges past the gate.  ``run()`` (the micro rows) also feeds
``benchmarks.run``.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import entropy_exit, flash_attention, rwkv_wkv
from repro.kernels.ref import (entropy_exit_ref, flash_attention_ref,
                               rwkv_wkv_ref)

#: archs for the model-layer leg: one attention-routed, one wkv-routed
MODEL_ARCHS = ("glm4-9b", "rwkv6-3b")


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)                     # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> List[dict]:
    """Micro rows: one kernel per row, interpret-mode Pallas vs oracle."""
    rng = np.random.default_rng(0)
    rows = []

    q = jnp.array(rng.normal(size=(1, 4, 128, 64)), jnp.float32)
    k = jnp.array(rng.normal(size=(1, 2, 128, 64)), jnp.float32)
    v = jnp.array(rng.normal(size=(1, 2, 128, 64)), jnp.float32)
    t = _time(flash_attention, q, k, v, interpret=True)
    err = float(jnp.abs(flash_attention(q, k, v, interpret=True)
                        - flash_attention_ref(q, k, v)).max())
    rows.append({"table": "kernels", "name": "flash_attention_128",
                 "us_per_call": round(t, 1), "max_err": err})

    x = jnp.array(rng.normal(size=(32, 8192)) * 2, jnp.float32)
    t = _time(entropy_exit, x, 1.5, interpret=True)
    H, _ = entropy_exit(x, 1.5, interpret=True)
    Hr, _ = entropy_exit_ref(x, 1.5)
    rows.append({"table": "kernels", "name": "entropy_exit_8k",
                 "us_per_call": round(t, 1),
                 "max_err": float(jnp.abs(H - Hr).max())})

    r = jnp.array(rng.normal(size=(2, 128, 4, 32)), jnp.float32)
    kk = jnp.array(rng.normal(size=(2, 128, 4, 32)), jnp.float32)
    vv = jnp.array(rng.normal(size=(2, 128, 4, 32)), jnp.float32)
    lw = -jnp.array(rng.uniform(0.05, 1.0, size=(2, 128, 4, 32)), jnp.float32)
    u = jnp.array(rng.normal(size=(4, 32)), jnp.float32)
    t = _time(rwkv_wkv, r, kk, vv, lw, u, interpret=True)
    y = rwkv_wkv(r, kk, vv, lw, u, interpret=True)

    def flat(x):
        return jnp.moveaxis(x, 2, 1).reshape(8, 128, 32)

    yr = rwkv_wkv_ref(flat(r), flat(kk), flat(vv), flat(lw),
                      jnp.broadcast_to(u[None], (2, 4, 32)).reshape(8, 32))
    yr = jnp.moveaxis(yr.reshape(2, 4, 128, 32), 1, 2)
    rows.append({"table": "kernels", "name": "rwkv_wkv_128",
                 "us_per_call": round(t, 1),
                 "max_err": float(jnp.abs(y - yr).max())})
    return rows


def run_model_level(archs=MODEL_ARCHS, batch: int = 2, seq_len: int = 16,
                    tau_frac: float = 0.9, seed: int = 0) -> List[dict]:
    """The dispatch leg: the routed call sites exercised through the real
    model layer.  Per arch, one jitted ``backbone_forward`` under each
    backend (fwd timing + logits delta) plus a decode serve-step tick
    (gate entropy delta + gate agreement) on the first arch."""
    from repro import configs as configs_mod
    from repro.api.serve_session import serve_step_config
    from repro.core.spmd import make_serve_step
    from repro.models.backbone import backbone_forward, init_backbone

    rows = []
    for arch in archs:
        base = configs_mod.get(arch).smoke()
        params = init_backbone(jax.random.PRNGKey(seed), base)
        toks = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                  (batch, seq_len), 0, base.vocab_size)
        t_us, logits = {}, {}
        for kn in ("ref", "pallas"):
            cfg = base.with_(kernels=kn)
            fwd = jax.jit(lambda p, t, cfg=cfg:
                          backbone_forward(p, cfg, tokens=t).logits)
            t_us[kn] = _time(fwd, params, toks)
            logits[kn] = fwd(params, toks)
        rows.append({
            "table": "kernel_dispatch",
            "name": f"backbone_forward/{arch}",
            "us_per_call": round(t_us["pallas"], 1),
            "ref_us_per_call": round(t_us["ref"], 1),
            "max_err": float(jnp.abs(logits["pallas"]
                                     - logits["ref"]).max()),
        })

    # serve-step gate leg: Alg.-3 tick with the entropy gate routed
    base = configs_mod.get(archs[0]).smoke()
    tau = tau_frac * float(np.log(base.vocab_size))
    params = init_backbone(jax.random.PRNGKey(seed), base)
    toks = jax.random.randint(jax.random.PRNGKey(seed + 2), (batch, 4), 0,
                              base.vocab_size)
    t_us, got = {}, {}
    for kn in ("ref", "pallas"):
        cfg = base.with_(kernels=kn)
        sc, _, _ = serve_step_config(cfg, tau=tau, boundary=0)
        step = jax.jit(make_serve_step(sc, boundary=0))
        t_us[kn] = _time(step, params, toks, None, None)
        got[kn] = step(params, toks, None, None)
    H = np.asarray(got["ref"]["entropy"])
    sure = np.abs(H - tau) > 1e-3        # off-threshold gate decisions
    rows.append({
        "table": "kernel_dispatch",
        "name": f"serve_step_gate/{archs[0]}",
        "us_per_call": round(t_us["pallas"], 1),
        "ref_us_per_call": round(t_us["ref"], 1),
        "max_err": float(np.abs(np.asarray(got["pallas"]["entropy"])
                                - H).max()),
        "gate_mismatches": int((np.asarray(got["pallas"]["exited"])[sure]
                                != np.asarray(got["ref"]["exited"])[sure])
                               .sum()),
    })
    return rows


def run_manifest(out: str = "BENCH_kernels.json", batch: int = 2,
                 seq_len: int = 16, seed: int = 0) -> Dict:
    """Full manifest: micro rows + model-layer dispatch rows + the parity
    summary the CI gate reads."""
    micro = run()
    model_level = run_model_level(batch=batch, seq_len=seq_len, seed=seed)
    parity = {
        "max_micro_err": max(r["max_err"] for r in micro),
        "max_model_err": max(r["max_err"] for r in model_level),
        "gate_mismatches": sum(r.get("gate_mismatches", 0)
                               for r in model_level),
    }
    result = {
        "benchmark": "kernel_dispatch",
        "config": {"archs": list(MODEL_ARCHS), "batch": batch,
                   "seq_len": seq_len, "seed": seed,
                   "platform": jax.default_backend(),
                   "pallas_mode": ("native"
                                   if jax.default_backend() == "tpu"
                                   else "interpret")},
        "micro": micro,
        "model_level": model_level,
        "parity": parity,
    }
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--max-delta", type=float, default=0.0,
                    help="exit non-zero when any model-layer pallas-vs-ref "
                         "delta exceeds this bound or any off-threshold "
                         "gate decision flips (the CI kernels-smoke gate; "
                         "0 disables)")
    args = ap.parse_args()
    r = run_manifest(out=args.out, batch=args.batch, seq_len=args.seq_len,
                     seed=args.seed)

    for row in r["micro"] + r["model_level"]:
        extra = (f"  ref {row['ref_us_per_call']:.0f}us"
                 if "ref_us_per_call" in row else "")
        print(f"{row['name']:<30} {row['us_per_call']:>10.1f}us{extra}  "
              f"max_err {row['max_err']:.2e}")
    pa = r["parity"]
    print(f"parity: micro {pa['max_micro_err']:.2e}, model "
          f"{pa['max_model_err']:.2e}, gate mismatches "
          f"{pa['gate_mismatches']}  -> {args.out}")

    if args.max_delta > 0:
        if pa["max_model_err"] > args.max_delta or pa["gate_mismatches"]:
            print(f"FAIL: kernels=pallas diverged from kernels=ref "
                  f"(--max-delta {args.max_delta:g})")
            sys.exit(1)
        print(f"parity gate ok (<= {args.max_delta:g})")


if __name__ == "__main__":
    main()
