"""Fused SPMD Hetero-SplitEE train/serve steps for the production backbone.

This is the *scalable* formulation of the paper (docs/DESIGN.md §2): client
groups
tile the batch (and hence the ``data`` mesh axis); every shard runs the full
network; the paper's gradient routing appears as per-example stop-gradients
at the split boundaries (in ``models/backbone.py``), and Eq. (1) cross-layer
aggregation appears as per-layer gradient normalization over participation
counts.

Two gradient modes:
  * ``eq1``  (paper-faithful): client-family and server-family gradients are
    pulled separately through one shared forward (two VJP passes) and each
    layer's gradient is normalized by its participation count —
    1/|{g : l_g > l}| for the client family, 1/|C_l| for the server family —
    which is exactly the every-round FedAvg limit of Algorithm 2.
  * ``sum`` (beyond-paper optimized): one backward pass of the summed loss,
    no per-layer renormalization.  Halves backward FLOPs; recorded separately
    in docs/EXPERIMENTS.md §Perf.

The step functions are pure and jit/pjit-friendly; ``launch/dryrun.py`` and
``launch/serve.py`` wrap them in ``jax.jit`` with mesh shardings.

This module also hosts the **TrainState-boundary** cohort step
(:func:`make_cohort_train_step`): the same client/server split semantics
expressed over the ``{"trainable", "state"}`` state dicts of the
``repro.api`` engine contract, with the two gradient modes above.  The
fused engine vmaps it over cohort lanes on one device; the spmd engine
stages the identical step under a jit whose batch dimension is sharded
over the mesh's ``data`` axis (``repro.api.spmd_engine``).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import (HeteroProfile, ModelConfig, OptimizerConfig,
                          SplitEEConfig, TrainConfig)
from repro.core.aggregation import participation_counts
from repro.core.losses import accuracy, softmax_cross_entropy, softmax_entropy
from repro.kernels import dispatch
from repro.models.backbone import BackboneOutput, backbone_forward, build_plan
from repro.optim import adam_update, make_schedule


# ---------------------------------------------------------------------------
# split-id assignment
# ---------------------------------------------------------------------------


def boundary_ids_for_batch(profile: HeteroProfile, cfg: ModelConfig,
                           batch: int) -> jnp.ndarray:
    """Per-example boundary index: group g (g-th contiguous slice of the
    batch) gets the boundary index of its split layer.  Split layers must be
    members of ``cfg.exit_layers``."""
    bounds = {l: b for b, l in enumerate(sorted(cfg.exit_layers))}
    ids = []
    per = batch // profile.num_groups
    rem = batch - per * profile.num_groups
    for g, li in enumerate(profile.split_layers):
        n = per + (1 if g < rem else 0)
        ids.extend([bounds[li]] * n)
    return jnp.asarray(ids, jnp.int32)


# ---------------------------------------------------------------------------
# per-layer participation scale trees (the Eq. 1 normalization)
# ---------------------------------------------------------------------------


def _bc(vals, leaf):
    """Broadcast a per-layer (length,) vector against a stacked leaf."""
    v = jnp.asarray(vals, jnp.float32)
    return v.reshape((-1,) + (1,) * (leaf.ndim - 1))


def participation_scale_trees(params: Any, cfg: ModelConfig,
                              profile: HeteroProfile) -> Tuple[Any, Any]:
    """Returns (client_scale, server_scale) pytrees shaped like ``params``.

    scale = 1/#participants for the family that trains the leaf, 0 when the
    family never reaches it (so scaled grads are exact, not just masked)."""
    N = profile.num_groups
    n_client, n_server = participation_counts(profile.split_layers,
                                              cfg.num_layers)
    inv = lambda n: (1.0 / n) if n > 0 else 0.0
    plan = build_plan(cfg)

    def zeros_like_scales(tree, val):
        return jax.tree.map(lambda _: jnp.float32(val), tree)

    cs: Dict[str, Any] = {}
    ss: Dict[str, Any] = {}
    # embedding / frontend: reached by every group's exit loss, never by the
    # server family (stop-gradient sits after them on every example's path).
    for key in ("embed", "frontend"):
        if key in params:
            cs[key] = zeros_like_scales(params[key], inv(N))
            ss[key] = zeros_like_scales(params[key], 0.0)
    if "shared_attn" in params:
        # Zamba2's shared block occurs on both sides of every cut; both
        # families touch it.  Use 1/N for each (documented approximation).
        cs["shared_attn"] = zeros_like_scales(params["shared_attn"], inv(N))
        ss["shared_attn"] = zeros_like_scales(params["shared_attn"], inv(N))

    cs_seg, ss_seg = [], []
    for si, seg in enumerate(plan):
        cs_runs, ss_runs = [], []
        for ri, run in enumerate(seg):
            p = params["segments"][si][ri]
            if run.shared:
                cs_runs.append({})
                ss_runs.append({})
                continue
            layers = range(run.start, run.start + run.length)
            cvals = [inv(n_client[l]) for l in layers]
            svals = [inv(n_server[l]) for l in layers]
            if run.length == 1:
                cs_runs.append(zeros_like_scales(p, cvals[0]))
                ss_runs.append(zeros_like_scales(p, svals[0]))
            else:
                cs_runs.append(jax.tree.map(lambda leaf: _bc(cvals, leaf), p))
                ss_runs.append(jax.tree.map(lambda leaf: _bc(svals, leaf), p))
        cs_seg.append(cs_runs)
        ss_seg.append(ss_runs)
    cs["segments"], ss["segments"] = cs_seg, ss_seg

    if "exit_heads" in params:
        exits = sorted(cfg.exit_layers)
        cs_heads, ss_heads = [], []
        for b, l in enumerate(exits):
            cnt = sum(1 for s in profile.split_layers if s == l)
            cs_heads.append(zeros_like_scales(params["exit_heads"][b], inv(cnt)))
            ss_heads.append(zeros_like_scales(params["exit_heads"][b], 0.0))
        cs["exit_heads"], ss["exit_heads"] = cs_heads, ss_heads

    cs["head"] = zeros_like_scales(params["head"], 0.0)
    ss["head"] = zeros_like_scales(params["head"], inv(N))
    return cs, ss


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def hetero_losses(out: BackboneOutput, labels: jnp.ndarray,
                  split_ids: jnp.ndarray, num_boundaries: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, Dict[str, jnp.ndarray]]:
    """(client_total, server_total, metrics).  ``client_total`` sums each
    boundary's masked-mean exit CE (one term per client group family);
    ``server_total`` is the final-head CE over all examples."""
    client_total = jnp.zeros((), jnp.float32)
    metrics: Dict[str, jnp.ndarray] = {}
    for b in range(num_boundaries):
        mask = (split_ids == b).astype(jnp.float32)
        if labels.ndim == 2:                       # (B, T) token labels
            m = mask[:, None] * jnp.ones_like(labels, jnp.float32)
        else:
            m = mask
        ce = softmax_cross_entropy(out.exit_logits[b], labels, m)
        ce = jnp.where(jnp.sum(mask) > 0, ce, 0.0)
        client_total = client_total + ce
        metrics[f"client_loss/b{b}"] = ce
    server_loss = softmax_cross_entropy(out.logits, labels)
    metrics["server_loss"] = server_loss
    metrics["aux_loss"] = out.aux_loss
    return client_total, server_loss + out.aux_loss, metrics


# ---------------------------------------------------------------------------
# train step factory
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StepConfig:
    model: ModelConfig
    splitee: SplitEEConfig
    train: TrainConfig
    grad_mode: str = "eq1"            # "eq1" | "sum"


def make_train_step(sc: StepConfig) -> Callable:
    """Builds ``train_step(params, opt_state, batch) -> (params, opt_state,
    metrics)``.  ``batch`` = {"tokens": (B,T), "labels": (B,T),
    "split_ids": (B,), ["embeds"/"enc": ...]}."""
    cfg = sc.model
    nb = len(cfg.exit_layers)
    schedule = make_schedule(sc.train.optimizer)
    remat = sc.train.remat != "none"

    def fwd_losses(params, batch):
        out = backbone_forward(params, cfg, tokens=batch.get("tokens"),
                               embeds=batch.get("embeds"),
                               enc=batch.get("enc"),
                               split_ids=batch["split_ids"], remat=remat)
        return hetero_losses(out, batch["labels"], batch["split_ids"], nb)

    def train_step(params, opt_state, batch):
        if sc.grad_mode == "eq1":
            def both(p):
                c, s, m = fwd_losses(p, batch)
                return jnp.stack([c, s]), m
            (losses, metrics), vjp = _vjp_aux(both, params)
            g_client = vjp(jnp.array([1.0, 0.0], jnp.float32))
            g_server = vjp(jnp.array([0.0, 1.0], jnp.float32))
            cs, ss = participation_scale_trees(params, cfg, sc.splitee.profile)
            grads = jax.tree.map(lambda gc, gs, a, b: gc * a + gs * b,
                                 g_client, g_server, cs, ss)
        else:
            def total(p):
                c, s, m = fwd_losses(p, batch)
                return c + s, m
            (loss, metrics), grads = jax.value_and_grad(total, has_aux=True)(params)

        lr = schedule(opt_state.step)
        new_params, new_opt = adam_update(params, grads, opt_state,
                                          sc.train.optimizer, lr)
        metrics["lr"] = lr
        return new_params, new_opt, metrics

    return train_step


def _vjp_aux(fn, params):
    """jax.vjp for fn(params) -> (primal, aux): returns ((primal, aux),
    pullback_on_primal)."""
    primal, vjp_fn, aux = jax.vjp(fn, params, has_aux=True)

    def pull(ct):
        (g,) = vjp_fn(ct)
        return g

    return (primal, aux), pull


# ---------------------------------------------------------------------------
# TrainState-boundary cohort step (the repro.api engine contract)
# ---------------------------------------------------------------------------


def make_cohort_train_step(model, opt_cfg, li: int,
                           grad_mode: str = "eq1") -> Callable:
    """One combined client+server step over the engine state-dict boundary:

        (client, copt, server, sopt, x, y, lr, lr_s)
            -> (client, copt, server, sopt, client_loss, server_loss)

    where ``client``/``server`` are ``{"trainable": ..., "state": ...}``
    dicts (the ``TrainState`` leaf layout, see repro/api/state.py), ``model``
    is a :class:`repro.api.protocol.SplitModel` adapter and ``li`` the
    cohort's cut layer.  Two gradient modes, mirroring the monolithic SPMD
    step above:

      * ``"eq1"`` — paper-faithful routing: the client family backprops its
        exit loss, the server family backprops the final loss, as two
        independent backward passes (exactly the composition the reference
        engine runs, so eq1 engines are cross-checkable to tolerance).
      * ``"sum"`` — one backward pass of the summed loss through the shared
        forward.  The split-boundary ``stop_gradient`` decouples the two
        parameter families, so the gradients are mathematically identical to
        eq1 — the mode trades the second VJP for one joint pass (recorded
        separately in benchmarks; convergence-tested, not bit-compared).

    Gradients never flow from server to client: ``h`` crosses the boundary
    through ``stop_gradient`` in both modes.  Both modes draw the per-side
    losses through ``strategies.client_loss_fn`` / ``server_loss_fn``, so
    adapter loss hooks (e.g. BackboneSplitModel's MoE load-balancing aux
    loss) reach every engine identically.
    """
    from repro.core.strategies import (client_loss_fn, make_client_step,
                                       make_server_step, server_loss_fn)

    if grad_mode == "eq1":
        cstep = make_client_step(model, opt_cfg)
        sstep = make_server_step(model, opt_cfg, li)

        def combined(client, copt, server, sopt, x, y, lr, lr_s):
            tr, st, copt, h, closs = cstep(client["trainable"],
                                           client["state"], copt, x, y, lr)
            h = jax.lax.stop_gradient(h)      # no server->client gradient
            srv, sst, sopt, sloss = sstep(server["trainable"],
                                          server["state"], sopt, h, y, lr_s)
            return ({"trainable": tr, "state": st}, copt,
                    {"trainable": srv, "state": sst}, sopt, closs, sloss)

        return combined

    if grad_mode != "sum":
        raise ValueError(f"unknown grad_mode {grad_mode!r}; expected "
                         f"'eq1' or 'sum'")

    closs_fn = client_loss_fn(model)
    sloss_fn = server_loss_fn(model, li)

    def joint_loss(ctr, strv, cst, sst, x, y):
        closs, (h, new_cst) = closs_fn(ctr, cst, x, y)
        h = jax.lax.stop_gradient(h)
        sloss, new_sst = sloss_fn(strv, sst, h, y)
        return closs + sloss, (closs, sloss, new_cst, new_sst)

    def combined(client, copt, server, sopt, x, y, lr, lr_s):
        (_, (closs, sloss, new_cst, new_sst)), (gc, gs) = jax.value_and_grad(
            joint_loss, argnums=(0, 1), has_aux=True)(
                client["trainable"], server["trainable"],
                client["state"], server["state"], x, y)
        tr, copt = adam_update(client["trainable"], gc, copt, opt_cfg, lr)
        srv, sopt = adam_update(server["trainable"], gs, sopt, opt_cfg, lr_s)
        return ({"trainable": tr, "state": new_cst}, copt,
                {"trainable": srv, "state": new_sst}, sopt, closs, sloss)

    return combined


# ---------------------------------------------------------------------------
# Sequential strategy at production scale (extension; Alg. 1 as SPMD)
# ---------------------------------------------------------------------------


def make_sequential_train_step(sc: StepConfig) -> Callable:
    """Alg. 1 fused into one jit program: a ``lax.scan`` over client groups.

    Each scan step processes ONE group's slice of the global batch: the
    client family (embed + layers <= l_g + exit head) updates from that
    group's exit loss, and the shared server side updates from the final
    loss with the paper's LR divisor (eta/N).  Deterministic order — the
    literal 'server processes features sequentially' semantics — while each
    per-group step still runs data/model-parallel on the mesh.

    Batch layout: group-contiguous (see ``boundary_ids_for_batch``); the
    batch must divide evenly by ``num_groups``.
    """
    cfg = sc.model
    nb = len(cfg.exit_layers)
    schedule = make_schedule(sc.train.optimizer)
    remat = sc.train.remat != "none"
    N = sc.splitee.profile.num_groups
    div = sc.splitee.resolved_server_lr_divisor()
    cs_cache: Dict[str, Any] = {}

    def group_loss(params, tokens, labels, split_ids):
        out = backbone_forward(params, cfg, tokens=tokens,
                               split_ids=split_ids, remat=remat)
        return hetero_losses(out, labels, split_ids, nb)

    def train_step(params, opt_state, batch):
        B = batch["tokens"].shape[0]
        per = B // N
        toks = batch["tokens"].reshape(N, per, -1)
        labs = batch["labels"].reshape(N, per, -1)
        sids = batch["split_ids"].reshape(N, per)
        lr = schedule(opt_state.step)

        cs, ss = participation_scale_trees(params, cfg, sc.splitee.profile)
        # sequential semantics: one group at a time; client-family grads at
        # full lr, server-family at lr / N (paper Table II).  One backward
        # pass cannot scale the two families separately on layers both reach,
        # so we blend by participation (exact on pure-client layers like the
        # embedding, scale 1, and pure-server layers like the head, 1/div).
        scale = jax.tree.map(
            lambda a, b: a * float(N) + b * float(N) / div, cs, ss)

        def body(carry, xs):
            p, o = carry
            t, l, s = xs

            def total(pp):
                c, srv, m = group_loss(pp, t, l, s)
                return c + srv, m

            (loss, m), g = jax.value_and_grad(total, has_aux=True)(p)
            g = jax.tree.map(lambda gg, sk: gg * sk, g, scale)
            p, o = adam_update(p, g, o, sc.train.optimizer, lr)
            return (p, o), m["server_loss"]

        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), (toks, labs, sids))
        return params, opt_state, {"server_loss": jnp.mean(losses),
                                   "lr": lr}

    return train_step


# ---------------------------------------------------------------------------
# serve step factory (decode shapes; Alg. 3 gate fused in)
# ---------------------------------------------------------------------------


def make_serve_step(sc: StepConfig, boundary: int = 0) -> Callable:
    """One-token decode step with the entropy gate computed at the client
    boundary.  TPU SPMD computes both the exit and the full path and selects
    (docs/DESIGN.md §2); the request-routing savings are realized by the
    batching engine (``repro.api.serve_session.ServeSession``, which vmaps
    this step over its decode slots).

    ``boundary`` indexes ``sorted(cfg.exit_layers)`` — the order
    ``backbone_forward`` emits ``exit_logits`` in — so the gate head sits
    after cut layer ``sorted(cfg.exit_layers)[boundary]``.

    The returned ``serve_step`` accepts an optional runtime ``tau``
    (defaults to ``sc.splitee.entropy_threshold``); passing it as a traced
    scalar lets threshold sweeps (the paper's Fig. 2 axis) reuse one
    compilation."""
    cfg = sc.model
    tau_default = sc.splitee.entropy_threshold
    backend = dispatch.backend_for(cfg)

    def serve_step(params, tokens, cache, cache_len, embeds=None, enc=None,
                   tau=None):
        tau_ = tau_default if tau is None else tau
        out = backbone_forward(params, cfg, tokens=tokens, embeds=embeds,
                               enc=enc, cache=cache, cache_len=cache_len)
        if out.exit_logits:
            e_logits = out.exit_logits[boundary]
            # Alg. 3 gate on the cfg.kernels backend (pallas = the fused
            # streaming-entropy kernel; tau stays a traced scalar)
            H, exit_now = backend.entropy_gate(e_logits, tau_)  # (B, T)
            final = jnp.where(exit_now[..., None], e_logits, out.logits)
        else:
            H = softmax_entropy(out.logits)
            exit_now = jnp.zeros_like(H, bool)
            final = out.logits
        return {"logits": final, "exited": exit_now, "entropy": H,
                "cache": out.cache}

    return serve_step
