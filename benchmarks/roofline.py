"""Roofline analysis (deliverable g): reads the dry-run artifacts
(experiments/artifacts/*.jsonl) and derives, per (arch x shape x mesh):

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s          [s]
    memory term     = HLO_bytes_per_device / HBM_bw               [s]
    collective term = collective_bytes_per_device / ICI link bw   [s]

HLO numbers are the trip-count-aware per-device totals from
``repro.launch.hlo_analysis`` (XLA's cost_analysis counts scan bodies once —
see that module).  MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill/decode),
N = active params (MoE counts shared + top_k/E of routed experts), D =
processed tokens; the ratio MODEL_FLOPS / (HLO_FLOPs x chips) exposes
replicated or remat-wasted compute.

The kernel-dispatch columns split out the FLOPs of the routed hot sites
(``repro.kernels.dispatch``: the GQA contraction and the RWKV6 wkv
recurrence) and name the backend a TPU run of this config would resolve —
``pallas`` rows run those FLOPs in the fused kernels, ``ref`` rows leave
them to XLA's own fusion.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

import jax

from repro import configs as configs_mod
from repro.config import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16, SHAPES_BY_NAME)

CHIPS = {"single_pod": 256, "multi_pod": 512}


# ---------------------------------------------------------------------------
# analytic parameter / flops model
# ---------------------------------------------------------------------------


def param_counts(arch: str, shape_name: str) -> Dict[str, float]:
    """(total, active) parameter counts from the abstract param tree."""
    from repro.launch.dryrun import arch_config
    from repro.launch.inputs import abstract_params

    cfg = arch_config(arch, shape_name)
    if cfg is None:
        return {"total": 0, "active": 0}
    params = abstract_params(cfg)
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        n = leaf.size
        total += n
        keys = [getattr(p, "key", "") for p in path if hasattr(p, "key")]
        is_expert = (cfg.moe is not None
                     and any(k in ("w_gate", "w_up", "w_down") for k in keys)
                     and "ffn" in keys)
        if is_expert and cfg.moe.num_experts > 1:
            active += n * cfg.moe.top_k / cfg.moe.num_experts
        else:
            active += n
    return {"total": float(total), "active": float(active)}


def model_flops(arch: str, shape_name: str) -> float:
    shape = SHAPES_BY_NAME[shape_name]
    pc = param_counts(arch, shape_name)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * pc["active"] * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * pc["active"] * tokens
    # decode: one token per sequence
    return 2.0 * pc["active"] * shape.global_batch


def routed_site_flops(arch: str, shape_name: str) -> Dict[str, object]:
    """FLOPs of the dispatch-routed sites for one program, plus the backend
    a TPU run of this config resolves (``auto`` -> ``pallas`` there)."""
    from repro.kernels import dispatch
    from repro.launch.dryrun import arch_config

    shape = SHAPES_BY_NAME[shape_name]
    cfg = arch_config(arch, shape_name)
    if cfg is None:
        return {"attention": 0.0, "wkv": 0.0, "kernels": "ref"}
    kind = "decode" if shape.kind == "decode" else "train"
    attn = dispatch.attention_site_flops(cfg, shape.global_batch,
                                         shape.seq_len, kind=kind)
    wkv = dispatch.wkv_site_flops(cfg, shape.global_batch, shape.seq_len,
                                  kind=kind)
    if shape.kind == "train":
        attn, wkv = 3.0 * attn, 3.0 * wkv       # fwd + bwd ~ 3x fwd
    return {"attention": attn, "wkv": wkv,
            "kernels": dispatch.resolve_kernels(cfg.kernels,
                                                platform="tpu")}


# ---------------------------------------------------------------------------
# terms
# ---------------------------------------------------------------------------


def terms(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    ana = rec.get("analysis", {})
    chips = CHIPS[rec["mesh"]]
    f = ana.get("flops_per_device", 0.0)
    b = ana.get("hbm_bytes_per_device", 0.0)
    c = ana.get("collective_total_per_device", 0.0)
    compute_s = f / PEAK_FLOPS_BF16
    memory_s = b / HBM_BW
    coll_s = c / ICI_BW
    dom = max((("compute", compute_s), ("memory", memory_s),
               ("collective", coll_s)), key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec["shape"])
    ratio = mf / (f * chips) if f else 0.0
    routed = routed_site_flops(rec["arch"], rec["shape"])
    routed_total = routed["attention"] + routed["wkv"]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "dominant": dom, "model_flops": mf,
        "useful_ratio": ratio,
        "kernels": routed["kernels"],
        "routed_attn_flops": routed["attention"],
        "routed_wkv_flops": routed["wkv"],
        "routed_frac": routed_total / mf if mf else 0.0,
        "peak_mem_gb": rec.get("memory", {}).get("peak_memory_bytes", 0) / 2**30,
        "grad_mode": rec.get("grad_mode", ""),
    }


MOVE_HINTS = {
    "compute": "shard the dominant matmuls over more of the mesh (raise "
               "useful_ratio) or drop remat recompute",
    "memory": "fuse elementwise chains / reduce activation re-materialization"
              " and keep weights resident (bigger per-chip batch)",
    "collective": "re-shard to contraction-friendly axes (Megatron-style "
                  "head/ffn sharding) so activations stop crossing ICI "
                  "every projection",
}


def load(path: str) -> List[dict]:
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def table(path: str, mesh: str = "single_pod") -> List[dict]:
    out = []
    for rec in load(path):
        if rec.get("mesh") != mesh:
            continue
        t = terms(rec)
        if t:
            t["hint"] = MOVE_HINTS[t["dominant"]]
            out.append(t)
        elif rec.get("status") == "skipped":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec["mesh"], "dominant": "skipped",
                        "hint": rec.get("reason", "")})
    return out


def run(path: str = "experiments/artifacts/dryrun_baseline.jsonl",
        mesh: str = "single_pod") -> List[dict]:
    rows = []
    for t in table(path, mesh):
        rows.append({"table": "roofline", **{
            k: (round(v, 6) if isinstance(v, float) else v)
            for k, v in t.items() if k != "hint"}})
    return rows


def markdown(path: str, mesh: str = "single_pod") -> str:
    rows = table(path, mesh)
    lines = [
        f"| arch | shape | compute s | memory s | collective s | dominant | "
        f"useful flops ratio | kernels | routed flops % | peak mem/dev GB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for t in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if t["dominant"] == "skipped":
            lines.append(f"| {t['arch']} | {t['shape']} | — | — | — | "
                         f"skipped | — | — | — | — |")
            continue
        lines.append(
            f"| {t['arch']} | {t['shape']} | {t['compute_s']:.3g} | "
            f"{t['memory_s']:.3g} | {t['collective_s']:.3g} | "
            f"**{t['dominant']}** | {t['useful_ratio']:.3f} | "
            f"{t['kernels']} | {t['routed_frac'] * 100:.1f} | "
            f"{t['peak_mem_gb']:.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    p = sys.argv[1] if len(sys.argv) > 1 else \
        "experiments/artifacts/dryrun_baseline.jsonl"
    print(markdown(p))
