"""Shared building blocks: initializers, norms, embeddings, linear layers.

Parameters are plain nested dicts of ``jnp.ndarray`` — no framework.  Every
``init_*`` function takes an ``rng`` and returns a pytree; every ``apply``-side
function takes ``(params, inputs, ...)`` and is pure.
"""
from __future__ import annotations

import math
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def trunc_normal(rng, shape, std: float, dtype) -> jnp.ndarray:
    """Truncated-normal init (2 sigma), the MaxText/PaLM default."""
    unscaled = jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32)
    return (unscaled * std).astype(dtype)


def fan_in_init(rng, shape, dtype, fan_in: Optional[int] = None) -> jnp.ndarray:
    fi = fan_in if fan_in is not None else shape[0]
    return trunc_normal(rng, shape, 1.0 / math.sqrt(max(1, fi)), dtype)


def zeros(shape, dtype) -> jnp.ndarray:
    return jnp.zeros(shape, dtype)


def ones(shape, dtype) -> jnp.ndarray:
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": ones((d,), dtype)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * params["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype) -> dict:
    return {"scale": ones((d,), dtype), "bias": zeros((d,), dtype)}


def layernorm(params: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    normed = (xf - mean) * jax.lax.rsqrt(var + eps)
    out = normed * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Linear / embedding
# ---------------------------------------------------------------------------


def init_linear(rng, d_in: int, d_out: int, dtype, bias: bool = False) -> dict:
    p = {"w": fan_in_init(rng, (d_in, d_out), dtype)}
    if bias:
        p["b"] = zeros((d_out,), dtype)
    return p


def linear(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    y = jnp.einsum("...i,io->...o", x, params["w"])
    if "b" in params:
        y = y + params["b"]
    return y


def init_embedding(rng, vocab: int, d: int, dtype) -> dict:
    return {"table": trunc_normal(rng, (vocab, d), 1.0, dtype)}


def embed(params: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Tied unembedding: project activations onto the embedding table."""
    return jnp.einsum("...d,vd->...v", x, params["table"])


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def split_rng(rng, names: Sequence[str]) -> dict:
    keys = jax.random.split(rng, len(names))
    return dict(zip(names, keys))
