"""``ServeSession`` — the continuous-batching serving engine.

Coverage (the PR's acceptance gates):

  * restore straight from a ``TrainSession`` checkpoint (manifest
    validation: kind, adapter identity) and serve it;
  * the continuously-batched decode stream matches a sequential
    ``make_serve_step`` reference run exactly — tokens AND gate decisions —
    per request, across ragged prompt lengths and decode budgets, with
    requests joining/leaving slots mid-stream;
  * slot reuse: more requests than slots, admission order preserved;
  * parameter reassembly picks the requested boundary's client/server pair
    and refuses boundaries no client trained;
  * the sticky exit policy serves client-only ticks once every active slot
    has adopted, and adopted slots keep serving exit-head tokens (matching
    the coherent-cache ``sequential_sticky_reference`` oracle) even when
    later admissions force mixed full-step ticks over their stale server
    cache pages;
  * ``serve_state_specs`` shards params by the recipe rules and the
    slot-paged cache over the mesh batch axes.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs as configs_mod
from repro.api import TrainSession
from repro.api.serve_session import (ServeSession, assemble_serve_params,
                                     sequential_reference,
                                     sequential_sticky_reference)
from repro.config import HeteroProfile, OptimizerConfig, SplitEEConfig
from repro.core.backbone_splitee import BackboneSplitModel
from repro.data.pipeline import ClientPartitioner
from repro.data.synthetic import SyntheticSeqClsDataset
from repro.models.backbone import init_backbone

TAU = 2.0


@pytest.fixture(scope="module")
def smoke_cfg():
    return configs_mod.get("glm4-9b").smoke()


@pytest.fixture(scope="module")
def params(smoke_cfg):
    return init_backbone(jax.random.PRNGKey(0), smoke_cfg)


def _prompts(cfg, n, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 10)))
            for _ in range(n)]


def _assert_parity(cfg, params, session, prompts, decodes, *, tau,
                   boundary, max_len):
    by_rid = {r.rid: r for r in session.results}
    assert sorted(by_rid) == list(range(len(prompts)))
    for rid, (p, d) in enumerate(zip(prompts, decodes)):
        ref = sequential_reference(cfg, params, p, d, tau=tau,
                                   boundary=boundary, max_len=max_len)
        got = by_rid[rid]
        assert got.tokens == ref.tokens, f"request {rid} tokens diverged"
        assert got.exited == ref.exited, f"request {rid} gate diverged"
        np.testing.assert_allclose(got.entropy, ref.entropy, atol=1e-4)


# ---------------------------------------------------------------------------
# continuous batching == sequential reference
# ---------------------------------------------------------------------------


def test_batched_stream_matches_sequential_reference(smoke_cfg, params):
    """More requests than slots, ragged prompts and budgets: every request's
    tokens, gate decisions, and entropies match a solo sequential run."""
    cfg = smoke_cfg
    prompts = _prompts(cfg, 6)
    decodes = [5, 8, 3, 6, 4, 7]
    sess = ServeSession(cfg, params, tau=TAU, boundary=0, slots=3,
                        max_len=32)
    for p, d in zip(prompts, decodes):
        sess.submit(p, decode_tokens=d)
    results = sess.run()
    assert len(results) == len(prompts)
    assert sess.stats.tokens == sum(decodes)
    _assert_parity(cfg, params, sess, prompts, decodes, tau=TAU,
                   boundary=0, max_len=32)


def test_deeper_boundary_parity(smoke_cfg, params):
    cfg = smoke_cfg
    prompts = _prompts(cfg, 3, seed=2)
    sess = ServeSession(cfg, params, tau=TAU, boundary=1, slots=2,
                        max_len=24)
    for p in prompts:
        sess.submit(p, decode_tokens=4)
    sess.run()
    _assert_parity(cfg, params, sess, prompts, [4] * 3, tau=TAU,
                   boundary=1, max_len=24)


def test_incremental_submit_joins_free_slots(smoke_cfg, params):
    """Requests submitted while the pool is mid-decode join without
    disturbing in-flight slots."""
    cfg = smoke_cfg
    prompts = _prompts(cfg, 4, seed=3)
    sess = ServeSession(cfg, params, tau=TAU, boundary=0, slots=2,
                        max_len=24)
    sess.submit(prompts[0], decode_tokens=6)
    sess.submit(prompts[1], decode_tokens=2)
    sess.step()
    sess.step()                      # rid 1 finishes, slot frees
    sess.submit(prompts[2], decode_tokens=3)
    sess.submit(prompts[3], decode_tokens=3)
    sess.run()
    _assert_parity(cfg, params, sess, prompts, [6, 2, 3, 3], tau=TAU,
                   boundary=0, max_len=24)


def test_runtime_tau_sweep_changes_gate(smoke_cfg, params):
    """tau is a runtime scalar: one session serves both an all-offload and
    an all-exit threshold (the Fig.-2 sweep path)."""
    cfg = smoke_cfg
    prompt = _prompts(cfg, 1)[0]
    sess = ServeSession(cfg, params, tau=0.0, boundary=0, slots=2,
                        max_len=24)
    sess.submit(prompt, decode_tokens=4)
    sess.run()
    assert sess.stats.exited == 0
    sess.tau = 1.1 * float(np.log(cfg.vocab_size))    # above max entropy
    sess.submit(prompt, decode_tokens=4)
    sess.run()
    assert sess.stats.exited == 4


def test_submit_rejects_overlong_request(smoke_cfg, params):
    sess = ServeSession(smoke_cfg, params, tau=TAU, slots=1, max_len=8)
    with pytest.raises(ValueError, match="exceed the slot page"):
        sess.submit(np.zeros(6, np.int32), decode_tokens=4)


def test_submit_rejects_nonpositive_decode_budget(smoke_cfg, params):
    """decode_tokens <= 0 would never hit the eviction check and hang
    run() on an immortal slot."""
    sess = ServeSession(smoke_cfg, params, tau=TAU, slots=1, max_len=8)
    for bad in (0, -3):
        with pytest.raises(ValueError, match="decode_tokens"):
            sess.submit(np.zeros(2, np.int32), decode_tokens=bad)


def test_bad_exit_policy_rejected(smoke_cfg, params):
    with pytest.raises(ValueError, match="exit_policy"):
        ServeSession(smoke_cfg, params, tau=TAU, exit_policy="eager")


# ---------------------------------------------------------------------------
# sticky policy
# ---------------------------------------------------------------------------


def test_sticky_policy_serves_client_only_ticks(smoke_cfg, params):
    """With tau above the max possible entropy every request adopts on its
    first gated token, and subsequent ticks skip the server sub-network."""
    cfg = smoke_cfg
    tau = 1.1 * float(np.log(cfg.vocab_size))
    sess = ServeSession(cfg, params, tau=tau, boundary=0, slots=2,
                        max_len=24, exit_policy="sticky")
    for p in _prompts(cfg, 2, seed=4):
        sess.submit(p, decode_tokens=5)
    results = sess.run()
    assert sess.stats.adoption_ratio == 1.0
    assert sess.stats.client_only_ticks > 0
    for r in results:
        assert all(r.exited)


def test_sticky_adoption_survives_later_admissions(smoke_cfg, params):
    """REVIEW regression: with more requests than slots, a slot that adopts
    goes through client-only ticks (server cache pages go stale) and is then
    dragged back into the full vmapped step when a new request joins a freed
    slot.  The sticky mask must keep it on the exit head — per-request
    streams must match the coherent-cache sequential sticky oracle exactly,
    even on ticks where the gate would not re-fire on its own."""
    cfg = smoke_cfg
    # seed chosen so a slot adopts, goes client-only, and then on a later
    # mixed full-step tick its natural gate would NOT re-fire — the exact
    # divergence the sticky mask guards (verified to fail without it)
    prompts = _prompts(cfg, 4, seed=9)
    decodes = [8, 2, 6, 5]
    # tau at the median probe entropy: gates fire on some ticks and not
    # others, so adopted and un-adopted slots coexist on full-step ticks
    probe = sequential_reference(cfg, params, prompts[0], 6, tau=0.0,
                                 boundary=0, max_len=24)
    tau = float(np.median(probe.entropy))
    sess = ServeSession(cfg, params, tau=tau, boundary=0, slots=2,
                        max_len=24, exit_policy="sticky")
    for p, d in zip(prompts, decodes):
        sess.submit(p, decode_tokens=d)
    results = sess.run()
    assert len(results) == len(prompts)
    flags = [f for r in results for f in r.exited]
    assert any(flags) and not all(flags)      # the scenario mixes paths
    by_rid = {r.rid: r for r in results}
    for rid, (p, d) in enumerate(zip(prompts, decodes)):
        ref = sequential_sticky_reference(cfg, params, p, d, tau=tau,
                                          boundary=0, max_len=24)
        got = by_rid[rid]
        assert got.tokens == ref.tokens, f"request {rid} tokens diverged"
        assert got.exited == ref.exited, f"request {rid} adoption diverged"
        np.testing.assert_allclose(got.entropy, ref.entropy, atol=1e-4)


def test_sticky_tokens_match_select_until_first_exit(smoke_cfg, params):
    """Before any slot adopts, sticky ticks run the same compute-both step,
    so a stream that never exits is identical under both policies."""
    cfg = smoke_cfg
    prompts = _prompts(cfg, 2, seed=5)
    outs = {}
    for policy in ("select", "sticky"):
        sess = ServeSession(cfg, params, tau=0.0, boundary=0, slots=2,
                            max_len=24, exit_policy=policy)
        for p in prompts:
            sess.submit(p, decode_tokens=4)
        outs[policy] = [r.tokens for r in sess.run()]
    assert outs["select"] == outs["sticky"]


# ---------------------------------------------------------------------------
# checkpoint restore
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def trained_ckpt(smoke_cfg, tmp_path_factory):
    """A short TrainSession run saved to disk, clients at both cuts."""
    cfg = smoke_cfg
    model = BackboneSplitModel(cfg, seed=0)
    ds = SyntheticSeqClsDataset(vocab_size=cfg.vocab_size, seq_len=8,
                                num_classes=8, train_size=64, test_size=32,
                                seed=0)
    parts = ClientPartitioner(2, seed=0).split(*ds.train)
    exits = sorted(cfg.exit_layers)
    session = TrainSession.from_config(
        model,
        SplitEEConfig(profile=HeteroProfile((exits[0], exits[1])),
                      strategy="averaging", entropy_threshold=TAU),
        OptimizerConfig(lr=1e-3, total_steps=16),
        parts, batch_size=16, engine="reference")
    session.train(rounds=2)
    path = str(tmp_path_factory.mktemp("serve_ckpt") / "ckpt-00000002")
    session.save(path)
    return path, model


def test_restore_serves_trained_checkpoint(trained_ckpt):
    """The tentpole acceptance path: restore a TrainSession checkpoint and
    serve a batched stream that matches the sequential reference on the
    reassembled trained parameters."""
    path, model = trained_ckpt
    cfg = model.cfg
    sess = ServeSession.restore(path, model, tau=TAU, boundary=0, slots=2,
                                max_len=24)
    assert sess.tau == TAU and sess.boundary == 0
    prompts = _prompts(cfg, 3, seed=6)
    for p in prompts:
        sess.submit(p, decode_tokens=4)
    sess.run()
    _assert_parity(cfg, sess.params, sess, prompts, [4] * 3, tau=TAU,
                   boundary=0, max_len=24)


def test_restore_defaults_from_manifest(trained_ckpt):
    """tau defaults to the checkpoint's entropy_threshold and boundary to
    the shallowest trained cut."""
    path, model = trained_ckpt
    sess = ServeSession.restore(path, model, slots=1, max_len=16)
    assert sess.tau == TAU
    assert sess.boundary == 0


def test_restore_deeper_boundary_uses_that_clients_exit_head(trained_ckpt):
    path, model = trained_ckpt
    sess = ServeSession.restore(path, model, boundary=1, slots=1,
                                max_len=16)
    assert sess.cut == sorted(model.cfg.exit_layers)[1]


def test_restore_refuses_wrong_model(trained_ckpt):
    path, _ = trained_ckpt
    other = BackboneSplitModel(configs_mod.get("minitron-8b").smoke(),
                               seed=0)
    with pytest.raises(ValueError, match="saved with model"):
        ServeSession.restore(path, other)


def test_assemble_refuses_untrained_boundary(smoke_cfg):
    """A checkpoint whose clients all sit at one cut cannot serve the
    other boundary."""
    cfg = smoke_cfg
    model = BackboneSplitModel(cfg, seed=0)
    from repro.api.state import init_train_state
    exits = sorted(cfg.exit_layers)
    state = init_train_state(
        model, SplitEEConfig(profile=HeteroProfile((exits[0], exits[0])),
                             strategy="averaging"),
        OptimizerConfig())
    with pytest.raises(ValueError, match="no client in the checkpoint"):
        assemble_serve_params(model, state, boundary=1)


def test_assembled_params_compose_trained_client_server(trained_ckpt):
    """The serving tree holds the boundary client's segments + exit head
    verbatim and its server's deep segments + LM head verbatim."""
    path, model = trained_ckpt
    from repro.api.serve_session import ServeSession as SS
    sess = SS.restore(path, model, boundary=0, slots=1, max_len=16)
    from repro.api.state import init_train_state
    from repro.checkpoint import load_pytree
    import json as _json
    with open(path + ".json") as f:
        meta = _json.load(f)["metadata"]
    sp = meta["splitee"]
    state = init_train_state(
        model, SplitEEConfig(profile=HeteroProfile(tuple(sp["split_layers"])),
                             strategy=sp["strategy"],
                             entropy_threshold=sp["entropy_threshold"]),
        OptimizerConfig(**{**meta["optimizer"],
                           "state_dtype": jnp.float32}))
    state = load_pytree(path, state)
    client = state.clients[0]["trainable"]
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(sess.params["embed"])[0]),
        np.asarray(jax.tree.leaves(client["embed"])[0]))
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(sess.params["exit_heads"][0])[0]),
        np.asarray(jax.tree.leaves(client["out"])[0]))
    server = state.servers[0]["trainable"]
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(sess.params["head"])[0]),
        np.asarray(jax.tree.leaves(server["head"])[0]))


# ---------------------------------------------------------------------------
# sharding
# ---------------------------------------------------------------------------


def test_serve_state_specs_shapes(smoke_cfg):
    """Params get the recipe rules; the cache's slot dim maps to the batch
    axes and its window dim to the model axis when divisible."""
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.launch.shardings import resolve_recipe, serve_state_specs
    from repro.models.backbone import init_cache

    cfg = smoke_cfg
    params = jax.eval_shape(
        lambda: init_backbone(jax.random.PRNGKey(0), cfg))
    cache = jax.eval_shape(lambda: init_cache(cfg, 4, 32, cfg.dtype))
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    specs = serve_state_specs(resolve_recipe("greedy"), mesh, params,
                              cache, cfg)
    assert set(specs) == {"params", "cache"}
    # structure mirrors the inputs exactly
    assert (jax.tree_util.tree_structure(
                jax.tree.map(lambda _: 0, specs["params"],
                             is_leaf=lambda x: isinstance(x, P)))
            == jax.tree_util.tree_structure(
                jax.tree.map(lambda _: 0, params)))


def test_session_with_mesh_places_state(smoke_cfg, params):
    """A 1x1 mesh exercises the device_put path end to end (multi-device
    placement is covered by the mesh-marked sharding tests)."""
    from jax.sharding import Mesh
    cfg = smoke_cfg
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    sess = ServeSession(cfg, params, tau=TAU, slots=2, max_len=24,
                        mesh=mesh, recipe="greedy")
    prompts = _prompts(cfg, 2, seed=7)
    for p in prompts:
        sess.submit(p, decode_tokens=3)
    sess.run()
    _assert_parity(cfg, params, sess, prompts, [3, 3], tau=TAU,
                   boundary=0, max_len=24)
