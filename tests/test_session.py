"""The unified TrainSession API: SplitModel protocol conformance, the
engine registry and auto-selection, full-test-set evaluation (tail batch
included), and the checkpoint/resume-equivalence guarantee across engines
(docs/API.md)."""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.api import (SplitModel, TrainSession, assert_split_model,
                       available_engines)
from repro.config import HeteroProfile, OptimizerConfig, SplitEEConfig
from repro.core.losses import softmax_entropy
from repro.core.splitee import MLPSplitModel, ResNetSplitModel
from repro.models.resnet import ResNetConfig

TOL = 1e-5


def _blob_data(n, d, classes, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(classes, d)) * 2.0
    y = rng.integers(0, classes, n).astype(np.int32)
    x = (centers[y] + rng.normal(size=(n, d))).astype(np.float32)
    return x, y


def _mlp_session(engine="auto", strategy="averaging", splits=(1, 2, 2, 3),
                 aggregate_every=1, n=600):
    x, y = _blob_data(n, 16, 3)
    k = len(splits)
    model = MLPSplitModel(in_dim=16, hidden=32, num_classes=3, num_layers=4,
                          seed=0)
    parts = [(x[i::k], y[i::k]) for i in range(k)]
    sess = TrainSession.from_config(
        model,
        SplitEEConfig(profile=HeteroProfile(tuple(splits)), strategy=strategy,
                      aggregate_every=aggregate_every),
        OptimizerConfig(lr=3e-3, total_steps=50),
        parts, batch_size=64, engine=engine)
    return sess, model, parts, (x, y)


def _assert_states_close(a, b, atol=TOL, msg=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for u, v in zip(la, lb):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v), atol=atol,
                                   err_msg=msg)


# ---------------------------------------------------------------------------
# SplitModel protocol conformance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("make_model", [
    lambda: MLPSplitModel(in_dim=8, hidden=16, num_classes=3, num_layers=4),
    lambda: ResNetSplitModel(ResNetConfig(num_classes=3, width_mult=0.125,
                                          image_size=16)),
], ids=["mlp", "resnet"])
def test_adapters_conform_to_split_model(make_model):
    model = make_model()
    assert isinstance(model, SplitModel)
    assert_split_model(model)                       # no raise
    # both adapters expose the SAME depth attribute
    assert isinstance(model.num_layers, int) and model.num_layers >= 4
    assert not hasattr(model, "num_layers_")        # dead alias removed
    # structural contract: client holds layers 1..li + exit head, server
    # holds li+1..L + head, keyed for Eq. (1) aggregation
    li = 2
    client, server = model.make_client(li), model.make_server(li)
    assert set(client) == {"trainable", "state"}
    assert set(client["trainable"]) == {"layers", "out"}
    assert set(client["trainable"]["layers"]) == {f"layer{k}"
                                                  for k in range(1, li + 1)}
    expected = {f"layer{k}" for k in range(li + 1, model.num_layers + 1)}
    assert set(server["trainable"]) == expected | {"head"}


def test_non_conforming_model_rejected():
    class NotASplitModel:
        num_layers = 4
    with pytest.raises(TypeError, match="SplitModel"):
        assert_split_model(NotASplitModel())
    x, y = _blob_data(60, 8, 3)
    with pytest.raises(TypeError, match="SplitModel"):
        TrainSession.from_config(
            NotASplitModel(),
            SplitEEConfig(profile=HeteroProfile((2,))),
            OptimizerConfig(), [(x, y)], batch_size=32)


# ---------------------------------------------------------------------------
# engine registry + auto-selection
# ---------------------------------------------------------------------------


def test_registry_lists_engines():
    assert {"reference", "fused", "spmd"} <= set(available_engines())


def test_auto_selects_widest_engine_for_averaging():
    """On one device auto degrades from spmd to fused, and engine_name
    reports the skip reason so manifests record the real execution path."""
    sess, *_ = _mlp_session(engine="auto", strategy="averaging")
    if len(jax.devices()) > 1:
        assert sess.engine.name == "spmd"
        assert sess.engine_name == "spmd"
    else:
        assert sess.engine.name == "fused"
        assert sess.engine_name.startswith("fused (spmd unavailable:")
        assert "device" in sess.engine_name


def test_explicit_engine_name_carries_no_note():
    sess, *_ = _mlp_session(engine="fused", strategy="averaging")
    assert sess.engine_name == "fused"


def test_auto_falls_back_to_reference_for_sequential():
    """Sequential is ordered across clients: auto must degrade to the
    reference engine instead of raising the way engine="fused" does."""
    sess, *_ = _mlp_session(engine="auto", strategy="sequential")
    assert sess.engine.name == "reference"
    assert "unavailable" in sess.engine_name
    with pytest.raises(ValueError, match="[Ss]equential"):
        _mlp_session(engine="fused", strategy="sequential")


def test_auto_falls_back_to_reference_for_ragged_cohorts():
    x, y = _blob_data(200, 16, 3)
    model = MLPSplitModel(in_dim=16, hidden=32, num_classes=3, num_layers=4)
    parts = [(x[:100], y[:100]), (x[100:140], y[100:140])]   # 100 vs 40
    cfg = SplitEEConfig(profile=HeteroProfile((2, 2)), strategy="averaging")
    sess = TrainSession.from_config(model, cfg, OptimizerConfig(), parts,
                                    batch_size=64, engine="auto")
    assert sess.engine.name == "reference"
    with pytest.raises(ValueError, match="batch"):
        TrainSession.from_config(model, cfg, OptimizerConfig(), parts,
                                 batch_size=64, engine="fused")


def test_unknown_engine_raises():
    with pytest.raises(ValueError, match="unknown engine"):
        _mlp_session(engine="warp")


@pytest.mark.skipif(len(jax.devices()) > 1,
                    reason="spmd is available on multi-device hosts")
def test_spmd_requires_devices_or_mesh():
    """Single-device host, no mesh: explicit engine="spmd" must fail with
    the actionable reason (tests/test_spmd_engine.py covers the engine
    itself on a forced multi-device host)."""
    with pytest.raises(ValueError, match="device"):
        _mlp_session(engine="spmd")


# ---------------------------------------------------------------------------
# evaluation covers the full test set (tail-batch regression)
# ---------------------------------------------------------------------------


def _manual_eval(model, state, sidx, i, li, x, y, tau):
    """Oracle: single full-batch forward in plain numpy over ALL samples."""
    client, server = state.clients[i], state.servers[sidx]
    h, clog, _ = model.client_forward(client["trainable"], client["state"],
                                      x, train=False)
    slog, _ = model.server_forward(server["trainable"], server["state"], h,
                                   li, train=False)
    cpred = np.asarray(clog).argmax(-1)
    spred = np.asarray(slog).argmax(-1)
    H = np.asarray(softmax_entropy(clog))
    apred = np.where(H < tau, cpred, spred)
    return (float((cpred == y).mean()), float((spred == y).mean()),
            float((apred == y).mean()), float((H < tau).mean()))


@pytest.mark.parametrize("engine", ["reference", "fused"])
def test_evaluate_scores_tail_batch(engine):
    """len(x) % batch_size != 0: the old loop silently dropped up to
    batch_size-1 trailing samples; evaluation must now score every sample
    (checked against a full-batch numpy oracle)."""
    sess, model, _, (x, y) = _mlp_session(engine=engine)
    sess.train(3)
    xt, yt = x[:130], y[:130]                       # 130 = 2*64 + 2 tail
    assert len(xt) % 64 != 0
    ev = sess.evaluate(xt, yt, batch_size=64)
    ad = sess.evaluate_adaptive(xt, yt, tau=0.5, batch_size=64)
    for i, li in enumerate(sess.ctx.profile.split_layers):
        ca, sa, aa, ratio = _manual_eval(model, sess.state, i, i, li,
                                         xt, yt, 0.5)
        assert abs(ev["client_acc"][i] - ca) < 1e-6
        assert abs(ev["server_acc"][i] - sa) < 1e-6
        assert abs(ad["acc"][i] - aa) < 1e-6
        assert abs(ad["client_ratio"][i] - ratio) < 1e-6


def test_evaluate_batch_size_invariant():
    """Accuracy must not depend on the evaluation batch size (the old loop
    silently dropped the tail batch)."""
    x, y = _blob_data(600, 16, 3)
    model = MLPSplitModel(in_dim=16, hidden=32, num_classes=3, num_layers=4,
                          seed=0)
    parts = [(x[i::3], y[i::3]) for i in range(3)]
    tr = TrainSession.from_config(
        model, SplitEEConfig(profile=HeteroProfile((1, 2, 3))),
        OptimizerConfig(lr=3e-3, total_steps=50),
        parts, batch_size=64, engine="reference")
    tr.train(2)
    # a 600-sample set at batch_size=512 used to score only 512 samples
    ev_512 = tr.evaluate(x, y, batch_size=512)
    ev_600 = tr.evaluate(x, y, batch_size=600)      # single exact batch
    np.testing.assert_allclose(ev_512["client_acc"], ev_600["client_acc"],
                               atol=1e-6)
    np.testing.assert_allclose(ev_512["server_acc"], ev_600["server_acc"],
                               atol=1e-6)


def test_evaluate_smaller_than_batch():
    sess, model, _, (x, y) = _mlp_session()
    sess.train(1)
    ev = sess.evaluate(x[:7], y[:7], batch_size=512)
    assert all(0.0 <= a <= 1.0 for a in ev["client_acc"] + ev["server_acc"])


# ---------------------------------------------------------------------------
# checkpoint / resume equivalence
# ---------------------------------------------------------------------------


def test_save_restore_roundtrips_full_state(tmp_path):
    """Every leaf of the TrainState (params, Adam moments, round counter,
    iterator cursors) survives save/restore bit-exactly, along with the
    metric history."""
    sess, model, parts, _ = _mlp_session(engine="fused")
    sess.train(3, local_epochs=2)
    path = os.path.join(tmp_path, "ckpt")
    sess.save(path)

    back = TrainSession.restore(path, model, parts)
    assert back.engine_name == "fused"
    assert back.round == 3
    assert int(np.asarray(back.state.batches_drawn)[0]) == 6
    _assert_states_close(back.state, sess.state, atol=0.0)
    assert [dataclasses.astuple(m) for m in back.history] == \
           [dataclasses.astuple(m) for m in sess.history]


def test_save_every_rotation_and_restore_latest(tmp_path):
    """train(save_every=2, keep_last=2) over 5 rounds checkpoints after
    rounds 2, 4 and 5, rotates down to the newest two, and restore_latest
    resumes from round 5 bit-exactly."""
    sess, model, parts, _ = _mlp_session(engine="fused")
    ckdir = os.path.join(tmp_path, "run")
    sess.train(5, save_every=2, save_dir=ckdir, keep_last=2)
    assert sess.round == 5
    stems = sorted(f[:-5] for f in os.listdir(ckdir) if f.endswith(".json"))
    assert stems == ["ckpt-00000004", "ckpt-00000005"]
    assert sorted(f for f in os.listdir(ckdir) if f.endswith(".npz")) == \
        ["ckpt-00000004.npz", "ckpt-00000005.npz"]

    back = TrainSession.restore_latest(ckdir, model, parts)
    assert back.round == 5
    _assert_states_close(back.state, sess.state, atol=0.0)


def test_restore_latest_skips_corrupt_newest(tmp_path):
    """A checkpoint truncated mid-write must not strand the run: the newest
    *valid* checkpoint wins, with a warning about the skipped one."""
    sess, model, parts, _ = _mlp_session(engine="fused")
    ckdir = os.path.join(tmp_path, "run")
    sess.train(4, save_every=2, save_dir=ckdir, keep_last=3)
    with open(os.path.join(ckdir, "ckpt-00000004.npz"), "wb") as f:
        f.write(b"truncated")
    with pytest.warns(UserWarning, match="skipping unreadable"):
        back = TrainSession.restore_latest(ckdir, model, parts)
    assert back.round == 2


def test_restore_latest_empty_dir_raises(tmp_path):
    model = MLPSplitModel(in_dim=8, hidden=16, num_classes=3, num_layers=4)
    with pytest.raises(FileNotFoundError, match="no readable"):
        TrainSession.restore_latest(str(tmp_path), model, [])


def test_save_every_requires_save_dir():
    sess, *_ = _mlp_session()
    with pytest.raises(ValueError, match="save_dir"):
        sess.train(2, save_every=1)


def test_save_rotating_gated_on_process_zero(tmp_path, monkeypatch):
    """Under a multi-process run every rank executes the save_every
    segmentation (identical dispatch per segment) but only process 0
    writes checkpoint files — a non-coordinator rank trains through the
    same segments and leaves the directory untouched."""
    import repro.api.session as session_mod

    rank0, *_ = _mlp_session(engine="fused")
    rank1, *_ = _mlp_session(engine="fused")
    d0, d1 = os.path.join(tmp_path, "r0"), os.path.join(tmp_path, "r1")

    rank0.train(4, save_every=2, save_dir=d0)
    monkeypatch.setattr(session_mod.jax, "process_index", lambda: 1)
    rank1.train(4, save_every=2, save_dir=d1)
    monkeypatch.undo()

    assert sorted(os.listdir(d0))                    # coordinator wrote
    assert not os.path.exists(d1)                    # rank 1 wrote nothing
    # ... and trained the exact same trajectory through the segments
    _assert_states_close(rank0.state, rank1.state, atol=0.0)


@pytest.mark.parametrize("engine", ["reference", "fused"])
def test_resume_equivalence(engine, tmp_path):
    """train 2k rounds == train k, save, restore, train k — on params, Adam
    moments, per-round metrics, and subsequent data order.  The save point
    (after round 3, aggregate_every=2) straddles an Eq. (1) aggregation
    boundary: round 3 aggregates, round 4 must not."""
    k, agg = 2, 2
    full, model, parts, _ = _mlp_session(engine=engine, aggregate_every=agg)
    full.train(2 * k, local_epochs=2)

    half, _, _, _ = _mlp_session(engine=engine, aggregate_every=agg)
    half.train(k, local_epochs=2)
    path = os.path.join(tmp_path, "ckpt")
    half.save(path)
    resumed = TrainSession.restore(path, model, parts)
    resumed.train(k, local_epochs=2)

    assert resumed.round == full.round == 2 * k
    _assert_states_close(resumed.state, full.state, msg=f"{engine} resume")
    assert len(resumed.history) == len(full.history)
    for a, b in zip(resumed.history, full.history):
        assert a.round == b.round
        assert abs(a.client_loss - b.client_loss) < TOL
        assert abs(a.server_loss - b.server_loss) < TOL


def test_resume_straddles_aggregation_boundary(tmp_path):
    """Save after an odd number of rounds with aggregate_every=2 so the
    restore lands between boundaries; the resumed run must aggregate at
    exactly the rounds the uninterrupted run does."""
    full, model, parts, _ = _mlp_session(engine="fused", aggregate_every=2)
    full.train(4)

    half, _, _, _ = _mlp_session(engine="fused", aggregate_every=2)
    half.train(3)                                   # boundary hit at t=1, 3
    path = os.path.join(tmp_path, "ckpt")
    half.save(path)
    resumed = TrainSession.restore(path, model, parts)
    resumed.train(1)                                # t=3 aggregates on resume

    _assert_states_close(resumed.state, full.state)
    # t=3 really aggregated: deepest common layers identical across servers
    for key in ("layer4", "head"):
        w0 = np.asarray(resumed.state.servers[0]["trainable"][key]["w"])
        for s in resumed.state.servers[1:]:
            np.testing.assert_allclose(
                w0, np.asarray(s["trainable"][key]["w"]), atol=1e-6)


@pytest.mark.parametrize("first,second", [("fused", "reference"),
                                          ("reference", "fused")])
def test_cross_engine_restore(first, second, tmp_path):
    """A state produced by one engine restores into the other and continues
    the same trajectory (both engines run numerically identical math)."""
    oracle, model, parts, _ = _mlp_session(engine="reference")
    oracle.train(4)

    half, _, _, _ = _mlp_session(engine=first)
    half.train(2)
    path = os.path.join(tmp_path, "ckpt")
    half.save(path)
    resumed = TrainSession.restore(path, model, parts, engine=second)
    assert resumed.engine_name == second
    resumed.train(2)

    _assert_states_close(resumed.state, oracle.state,
                         msg=f"{first}->{second}")
    for a, b in zip(resumed.history, oracle.history):
        assert abs(a.client_loss - b.client_loss) < TOL
        assert abs(a.server_loss - b.server_loss) < TOL


def test_restore_rejects_augment_mismatch(tmp_path):
    """The augment callable is not serializable, but whether one was active
    is part of the data-replay contract: restoring without it would resume
    on a silently different stream."""
    x, y = _blob_data(120, 16, 3)
    model = MLPSplitModel(in_dim=16, hidden=32, num_classes=3, num_layers=4)
    parts = [(x, y)]
    aug = lambda rng, bx: bx + rng.normal(size=bx.shape).astype(bx.dtype)
    sess = TrainSession.from_config(
        model, SplitEEConfig(profile=HeteroProfile((2,))),
        OptimizerConfig(total_steps=10), parts, batch_size=32,
        augment=aug)
    sess.train(1)
    path = os.path.join(tmp_path, "ckpt")
    sess.save(path)
    with pytest.raises(ValueError, match="augment"):
        TrainSession.restore(path, model, parts)           # augment dropped
    back = TrainSession.restore(path, model, parts, augment=aug)
    back.train(1)                                          # replays cleanly
    assert back.round == 2


def test_restore_rejects_non_session_checkpoint(tmp_path):
    from repro.checkpoint import save_pytree
    path = os.path.join(tmp_path, "raw")
    save_pytree(path, {"params": np.zeros(3)}, metadata={"arch": "x"})
    model = MLPSplitModel(in_dim=8, hidden=16, num_classes=3, num_layers=4)
    with pytest.raises(ValueError, match="not a TrainSession"):
        TrainSession.restore(path, model, [])


def test_resnet_state_roundtrip_includes_bn(tmp_path):
    """ResNet cohorts carry BatchNorm running statistics in the non-trainable
    state; they must ride through save/restore and keep the resumed
    trajectory on the uninterrupted one."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(96, 16, 16, 3)).astype(np.float32)
    y = rng.integers(0, 3, 96).astype(np.int32)
    parts = [(x[0::2], y[0::2]), (x[1::2], y[1::2])]
    model = ResNetSplitModel(ResNetConfig(num_classes=3, width_mult=0.125,
                                          image_size=16), seed=0)
    cfg = SplitEEConfig(profile=HeteroProfile((3, 4)), strategy="averaging")
    opt = OptimizerConfig(lr=1e-3, total_steps=10)

    full = TrainSession.from_config(model, cfg, opt, parts, batch_size=32,
                                    engine="reference")
    full.train(2)

    half = TrainSession.from_config(model, cfg, opt, parts, batch_size=32,
                                    engine="reference")
    half.train(1)
    # BN state moved away from init and is part of the saved tree
    bn_before = jax.tree.leaves(half.state.clients[0]["state"])
    assert bn_before, "ResNet client must carry BN state"
    path = os.path.join(tmp_path, "ckpt")
    half.save(path)
    resumed = TrainSession.restore(path, model, parts)
    _assert_states_close(resumed.state, half.state, atol=0.0)
    resumed.train(1)
    _assert_states_close(resumed.state, full.state)
