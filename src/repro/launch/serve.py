"""Adaptive serving driver: batched decode with the Alg.-3 entropy gate.

Demonstrates the Hetero-SplitEE inference contract end-to-end on a smoke
config: prefill a batch of prompts into the KV/state cache, then decode
tokens with the early-exit gate at the client boundary.  Reports the client
adoption ratio and the server-offload compute saving (layers skipped), which
is the quantity the paper's Fig. 2 trades against accuracy.

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --tau 2.0
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as configs_mod
from repro.config import HeteroProfile, SplitEEConfig, TrainConfig
from repro.core.spmd import StepConfig, make_serve_step
from repro.models.backbone import init_backbone, init_cache

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-tokens", type=int, default=32)
    ap.add_argument("--tau", type=float, default=2.0)
    ap.add_argument("--boundary", type=int, default=0,
                    help="exit boundary index used as the client cut")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs_mod.get(args.arch).smoke()
    profile = HeteroProfile(split_layers=(cfg.exit_layers[0],) * 4)
    sc = StepConfig(model=cfg,
                    splitee=SplitEEConfig(profile=profile,
                                          entropy_threshold=args.tau),
                    train=TrainConfig())
    rng = jax.random.PRNGKey(args.seed)
    params = init_backbone(rng, cfg)
    serve_step = jax.jit(make_serve_step(sc, boundary=args.boundary))

    B, P = args.batch, args.prompt_len
    max_len = P + args.decode_tokens
    cache = init_cache(cfg, B, max_len, cfg.dtype)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size)

    extra = {}
    if cfg.arch_type == "audio":
        extra["enc"] = jnp.zeros((B, cfg.cross_source_len, 768), cfg.dtype)

    # prefill (chunked cache fill)
    from repro.models.backbone import backbone_forward
    pre = backbone_forward(params, cfg, tokens=prompts, cache=cache,
                           cache_len=jnp.zeros((), jnp.int32), **extra)
    cache = pre.cache
    tok = jnp.argmax(pre.logits[:, -1:], -1)

    # the client sub-network is layers [0, cut); compute the fraction of
    # layers the early exit skips per exited token.
    cut = sorted(cfg.exit_layers)[args.boundary]
    skip_frac = 1.0 - cut / cfg.num_layers

    exited_total, n_total = 0, 0
    t0 = time.time()
    for i in range(args.decode_tokens):
        out = serve_step(params, tok, cache, jnp.asarray(P + i, jnp.int32),
                         **extra)
        cache = out["cache"]
        tok = jnp.argmax(out["logits"], -1)
        exited = np.asarray(out["exited"]).sum()
        exited_total += int(exited)
        n_total += B
    dt = time.time() - t0

    ratio = exited_total / max(1, n_total)
    print(f"arch={cfg.name} tau={args.tau} boundary={args.boundary} "
          f"(cut layer {cut}/{cfg.num_layers})")
    print(f"decoded {n_total} tokens in {dt:.2f}s  "
          f"client adoption ratio {ratio:.3f}")
    print(f"server compute skipped ~{ratio * skip_frac * 100:.1f}% of layer "
          f"work (exited tokens skip {skip_frac*100:.0f}% of layers)")


if __name__ == "__main__":
    main()
