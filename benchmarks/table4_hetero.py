"""Paper Table IV: heterogeneous client models.  12 clients: 4x end_layer=3,
4x end_layer=4, 4x end_layer=5 in ONE collaborative session; accuracy
reported per depth."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import make_dataset, mean_by_depth, run_strategy

SPLITS = (3,) * 4 + (4,) * 4 + (5,) * 4
METHODS = ("sequential", "averaging", "centralized", "distributed")


def run(rounds: int = 25, train_size: int = 1800, test_size: int = 384,
        datasets=("syn10", "syn100"), seed: int = 0, engine: str = "auto"
        ) -> List[dict]:
    """``engine`` selects the TrainSession execution backend per cell
    ("auto" = fused where valid, reference for sequential/centralized)."""
    rows = []
    for ds_name in datasets:
        ds = make_dataset(ds_name, train_size, test_size, seed=seed)
        for method in METHODS:
            t0 = time.time()
            ev = run_strategy(ds, method, SPLITS, rounds=rounds, seed=seed,
                              engine=engine)
            if method == "centralized":
                for li, c, s in zip(ev["split_layers"], ev["client_acc"],
                                    ev["server_acc"]):
                    rows.append({"table": "table4_hetero", "dataset": ds_name,
                                 "method": method, "layer": li,
                                 "server_acc": round(s, 4),
                                 "client_acc": round(c, 4),
                                 "wall_s": round(time.time() - t0, 1)})
                continue
            by = mean_by_depth(ev, SPLITS)
            for li, accs in sorted(by.items()):
                rows.append({"table": "table4_hetero", "dataset": ds_name,
                             "method": method, "layer": li,
                             "server_acc": round(accs["server"], 4),
                             "client_acc": round(accs["client"], 4),
                             "wall_s": round(time.time() - t0, 1)})
    return rows
