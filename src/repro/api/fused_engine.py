"""Fused engine: scan + vmap whole-chunk execution as a pure
``TrainState -> TrainState`` executor (see docs/ENGINES.md).

  * **Cohorts + vmap** — clients sharing a split layer have identical pytree
    structure, so each cohort is stacked along a leading lane axis and its
    combined client+server step runs under one ``jax.vmap``.
  * **Rounds under lax.scan** — the exact minibatch sequence the reference
    engine would draw is pre-staged as ``[rounds, k, E, B, ...]`` device
    tensors and the whole chunk rolls through one ``jax.lax.scan`` with
    donated carry; losses come back as stacked per-round arrays (one host
    sync per chunk).
  * **In-graph Eq. (1)** — ``stacked_cross_layer_aggregate`` under a
    ``lax.cond`` on the traced ``(t+1) % aggregate_every == 0`` predicate.

Numerically equivalent to the reference engine in ``eq1`` grad mode (both
compose the same client/server step math through
``core.spmd.make_cohort_train_step``); enforced by
``tests/test_fused_engine.py`` and ``tests/test_session.py``.  The
Sequential strategy (Alg. 1) is inherently ordered across clients and is
not supported — ``resolve_engine("auto", ...)`` falls back to the
reference engine for it.

``repro.api.spmd_engine.SpmdEngine`` subclasses this engine and overrides
the :meth:`FusedEngine._compile_chunk` (jit with mesh shardings),
:meth:`FusedEngine._put_batch` (host batch -> sharded device placement)
and :meth:`FusedEngine._stack_carry` (replicated carry) hooks to stage
the identical round body with mesh shardings.
"""
from __future__ import annotations

import os
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.engines import (Engine, SessionContext, cohort_layout,
                               ragged_cohort_reason, register_engine)
from repro.api.state import TrainState
from repro.core.aggregation import stacked_cross_layer_aggregate
from repro.core.splitee import stack_pytrees, unstack_pytrees
from repro.core.spmd import make_cohort_train_step
from repro.core.strategies import RoundMetrics
from repro.data.pipeline import effective_batch_size, prestage_batches


@register_engine("fused")
class FusedEngine(Engine):

    #: staging budget (bytes) for the auto ``chunk_rounds`` default: when a
    #: run's whole pre-staged ``[rounds, k, E, B, ...]`` tensor would exceed
    #: it, the run is split into budget-sized chunks instead of silently
    #: staging everything (full-size configs OOM before the first step
    #: otherwise).  Override per instance, or via REPRO_STAGE_BUDGET_MB.
    stage_budget_bytes: int = 1 << 30

    def __init__(self, ctx: SessionContext):
        super().__init__(ctx)
        self._cohort_lis, self._lanes = cohort_layout(
            ctx.profile.split_layers)
        self._counts: Dict[int, int] = {li: len(v)
                                        for li, v in self._lanes.items()}
        self._chunk_fns: Dict[int, Callable] = {}

    @classmethod
    def supports(cls, ctx: SessionContext):
        if ctx.strategy not in ("averaging", "distributed"):
            return (f"supports averaging/distributed only, not "
                    f"{ctx.strategy!r} (the Sequential strategy is ordered "
                    f"across clients — use the reference engine)")
        return ragged_cohort_reason(ctx)

    # -------------------------------------------------------------- tracing
    def _vstep(self, li: int) -> Callable:
        """One cohort step: the shared ``core.spmd.make_cohort_train_step``
        (eq1: exactly the reference engine's round body; sum: one fused
        backward of the summed loss), vmapped over lanes."""
        combined = make_cohort_train_step(self.ctx.model, self.ctx.opt_cfg,
                                          li, self.ctx.grad_mode)
        return jax.vmap(combined, in_axes=(0, 0, 0, 0, 0, 0, None, None))

    def _compile_chunk(self, chunk: Callable) -> Callable:
        """Stage the traced chunk.  The spmd subclass overrides this with
        mesh in/out shardings; here it is a plain donated jit."""
        return jax.jit(chunk, donate_argnums=(0,))

    def _chunk_fn(self, local_epochs: int) -> Callable:
        """Jitted ``(carry, ts, xs, ys) -> (carry, (closs[n], sloss[n]))``
        scanning the round body over a chunk; carry buffers are donated."""
        if local_epochs in self._chunk_fns:
            return self._chunk_fns[local_epochs]

        ctx = self.ctx
        cohort_lis = self._cohort_lis
        counts = self._counts
        vsteps = {li: self._vstep(li) for li in cohort_lis}
        denom = float(ctx.N * local_epochs)
        averaging = ctx.strategy == "averaging"
        agg_every = ctx.cfg.aggregate_every
        schedule, lr_div = ctx.schedule, ctx.server_lr_div

        def epoch_body(carry, bx, by, lr, lr_s):
            out, csum, ssum = {}, 0.0, 0.0
            for li in cohort_lis:
                client, copt, server, sopt = carry[li]
                client, copt, server, sopt, closs, sloss = vsteps[li](
                    client, copt, server, sopt, bx[li], by[li], lr, lr_s)
                out[li] = (client, copt, server, sopt)
                csum = csum + jnp.sum(closs)
                ssum = ssum + jnp.sum(sloss)
            return out, (csum, ssum)

        def round_body(carry, inp):
            t, xs, ys = inp
            lr = schedule(t)
            lr_s = lr / lr_div

            def body(c, data):
                return epoch_body(c, data[0], data[1], lr, lr_s)

            carry, (cs, ss) = jax.lax.scan(body, carry, (xs, ys))
            if averaging:
                def aggregated(c):
                    tr = stacked_cross_layer_aggregate(
                        {li: c[li][2]["trainable"] for li in cohort_lis},
                        counts)
                    st = stacked_cross_layer_aggregate(
                        {li: c[li][2]["state"] for li in cohort_lis},
                        counts)
                    return {li: (c[li][0], c[li][1],
                                 {"trainable": tr[li], "state": st[li]},
                                 c[li][3])
                            for li in cohort_lis}

                # cond (not where) so non-boundary rounds skip the Eq. (1)
                # means entirely — still in-graph, still no host sync
                do = ((t + 1) % agg_every) == 0
                carry = jax.lax.cond(do, aggregated, lambda c: c, carry)
            return carry, (jnp.sum(cs) / denom, jnp.sum(ss) / denom)

        def chunk(carry, ts, xs, ys):
            return jax.lax.scan(round_body, carry, (ts, xs, ys))

        fn = self._compile_chunk(chunk)
        self._chunk_fns[local_epochs] = fn
        return fn

    # ------------------------------------------------------------- staging
    def _put_batch(self, arr: np.ndarray, li: int) -> jnp.ndarray:
        """Host-staged batch for cohort ``li`` -> device.  The spmd subclass
        overrides this to place each device's slice directly into the
        cohort's batch sharding."""
        return jnp.asarray(arr)

    def _stage_chunk(self, rounds: int, local_epochs: int):
        """Draw the chunk's minibatches through the session's data cursor
        (the same sequence the reference engine would consume) and stack
        them as ``{li: [rounds, k, E, B, ...]}`` device arrays."""
        def drawn(i):
            while True:
                yield self.ctx.data.draw(i)

        per_client = [prestage_batches(drawn(i), rounds, local_epochs)
                      for i in range(self.ctx.N)]
        xs, ys = {}, {}
        for li in self._cohort_lis:
            lanes = self._lanes[li]
            xs[li] = self._put_batch(np.stack([per_client[i][0]
                                               for i in lanes], axis=2), li)
            ys[li] = self._put_batch(np.stack([per_client[i][1]
                                               for i in lanes], axis=2), li)
        return xs, ys

    def _round_stage_bytes(self, local_epochs: int) -> int:
        """Host bytes one round of pre-staged batches occupies (every
        client's ``local_epochs`` minibatches, x and y)."""
        total = 0
        for x, y in self.ctx.client_data:
            eb = effective_batch_size(len(x), self.ctx.batch_size)
            per_example = (x.dtype.itemsize * int(np.prod(x.shape[1:]))
                           + y.dtype.itemsize * int(np.prod(y.shape[1:])))
            total += local_epochs * eb * per_example
        return total

    def _auto_chunk_rounds(self, rounds: int, local_epochs: int) -> int:
        """The default chunk size when the caller passed ``chunk_rounds=0``:
        as many rounds as fit the staging budget (at least one).  An
        explicit per-instance ``stage_budget_bytes`` wins over the
        REPRO_STAGE_BUDGET_MB environment default."""
        budget = self.stage_budget_bytes
        env = os.environ.get("REPRO_STAGE_BUDGET_MB")
        if env and budget == FusedEngine.stage_budget_bytes:
            try:
                budget = int(env) << 20
            except ValueError:
                raise ValueError(
                    f"REPRO_STAGE_BUDGET_MB={env!r} is not an integer "
                    f"megabyte count") from None
        per_round = max(1, self._round_stage_bytes(local_epochs))
        return max(1, min(rounds, budget // per_round))

    def _stack_carry(self, clients, copts, servers, sopts):
        model = self.ctx.model
        carry = {}
        for li in self._cohort_lis:
            lanes = self._lanes[li]
            carry[li] = (
                model.stack_clients([clients[i] for i in lanes]),
                stack_pytrees([copts[i] for i in lanes]),
                model.stack_clients([servers[i] for i in lanes]),
                stack_pytrees([sopts[i] for i in lanes]),
            )
        return carry

    def _unstack_carry(self, carry, clients, copts, servers, sopts):
        for li in self._cohort_lis:
            lanes = self._lanes[li]
            cs, co, ss, so = (unstack_pytrees(t, len(lanes))
                              for t in carry[li])
            for j, i in enumerate(lanes):
                clients[i], copts[i] = cs[j], co[j]
                servers[i], sopts[i] = ss[j], so[j]

    # ------------------------------------------------------------ training
    def run(self, state: TrainState, rounds: int, local_epochs: int = 1,
            log_every: int = 0, chunk_rounds: int = 0
            ) -> Tuple[TrainState, List[RoundMetrics]]:
        """``chunk_rounds`` bounds how many rounds of pre-staged data are
        resident at once (0 = auto: the whole run when it fits the staging
        budget, budget-sized chunks otherwise — chunking never changes the
        trajectory, see docs/ENGINES.md)."""
        self.ctx.data.align(state.batches_drawn)
        chunk = (chunk_rounds if chunk_rounds > 0
                 else self._auto_chunk_rounds(rounds, local_epochs))
        metrics: List[RoundMetrics] = []
        done = 0
        while done < rounds:
            n = min(chunk, rounds - done)
            state, ms = self._run_chunk(state, n, local_epochs, log_every)
            metrics.extend(ms)
            done += n
        return state, metrics

    def _run_chunk(self, state: TrainState, n: int, local_epochs: int,
                   log_every: int) -> Tuple[TrainState, List[RoundMetrics]]:
        clients, copts = list(state.clients), list(state.client_opts)
        servers, sopts = list(state.servers), list(state.server_opts)
        t0 = int(state.round)

        xs, ys = self._stage_chunk(n, local_epochs)
        ts = jnp.arange(t0, t0 + n, dtype=jnp.int32)
        carry, (closs, sloss) = self._chunk_fn(local_epochs)(
            self._stack_carry(clients, copts, servers, sopts), ts, xs, ys)
        self._unstack_carry(carry, clients, copts, servers, sopts)

        closs, sloss = np.asarray(closs), np.asarray(sloss)  # one sync
        metrics = []
        for r in range(n):
            m = RoundMetrics(t0 + r, float(closs[r]), float(sloss[r]))
            metrics.append(m)
            if log_every and (m.round % log_every == 0):
                print(f"round {m.round:4d}  client_loss {m.client_loss:.4f}"
                      f"  server_loss {m.server_loss:.4f}")

        new_state = state.replace(
            clients=tuple(clients), client_opts=tuple(copts),
            servers=tuple(servers), server_opts=tuple(sopts),
            round=jnp.asarray(t0 + n, jnp.int32),
            batches_drawn=state.batches_drawn
            + jnp.asarray(n * local_epochs, jnp.int32))
        return new_state, metrics
