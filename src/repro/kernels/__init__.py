"""Pallas kernels for the three hot sites plus the backend dispatch that
routes the model layer onto them.

Layout:

  * ``flash_attention.py`` / ``rwkv_wkv.py`` / ``entropy_exit.py`` — the raw
    Pallas kernels (TPU target; interpret mode off-TPU).
  * ``ops.py``    — jit'd public wrappers: shape padding, dtype handling,
    traced runtime scalars (``tau``, ``kv_valid``), interpret default.
  * ``ref.py``    — pure-jnp oracles, the ground truth every kernel is
    equivalence-gated against in tier-1.
  * ``dispatch.py`` — the :class:`~repro.kernels.dispatch.KernelBackend`
    registry behind the ``ModelConfig.kernels`` knob
    (``{"auto", "pallas", "ref"}``; auto = pallas on TPU, ref elsewhere).

Backend contract: backends take model-layer layouts, return the reference
path's dtypes, and must match the reference within the per-site tolerances
in docs/ENGINES.md.  Training sites differentiate — the pallas backend runs
the kernel forward and the reference VJP backward (``jax.custom_vjp``
recompute), since Pallas kernels carry no autodiff rule.
"""
from repro.kernels import dispatch  # noqa: F401
from repro.kernels.dispatch import (KernelBackend,  # noqa: F401
                                    PallasBackend, ReferenceBackend,
                                    available_backends, backend_for,
                                    get_backend, register_backend,
                                    resolve_kernels)
from repro.kernels.ops import (entropy_exit, flash_attention,  # noqa: F401
                               rwkv_wkv)
