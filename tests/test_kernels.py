"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import entropy_exit, flash_attention, rwkv_wkv
from repro.kernels.ref import (entropy_exit_ref, flash_attention_ref,
                               rwkv_wkv_ref)

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("B,H,Hkv,T,S,D", [
    (2, 4, 2, 64, 64, 32),
    (1, 4, 1, 96, 96, 16),          # MQA, non-pow2 seq
    (2, 2, 2, 33, 33, 64),          # padding path
    (1, 8, 4, 128, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, Hkv, T, S, D, dtype):
    q = jnp.array(RNG.normal(size=(B, H, T, D)), dtype)
    k = jnp.array(RNG.normal(size=(B, Hkv, S, D)), dtype)
    v = jnp.array(RNG.normal(size=(B, Hkv, S, D)), dtype)
    out = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [8, 48])
def test_flash_attention_sliding_window(window):
    q = jnp.array(RNG.normal(size=(1, 2, 64, 32)), jnp.float32)
    k = jnp.array(RNG.normal(size=(1, 2, 64, 32)), jnp.float32)
    v = jnp.array(RNG.normal(size=(1, 2, 64, 32)), jnp.float32)
    out = flash_attention(q, k, v, window=window, block_q=16, block_k=16,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("B,V", [(8, 1000), (5, 4097), (16, 128),
                                 (3, 50000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_entropy_exit_sweep(B, V, dtype):
    x = jnp.array(RNG.normal(size=(B, V)) * 3, dtype)
    tau = 1.5
    H, ex = entropy_exit(x, tau, interpret=True)
    Hr, exr = entropy_exit_ref(x, tau)
    np.testing.assert_allclose(np.asarray(H), np.asarray(Hr), atol=1e-2,
                               rtol=1e-3)
    # decisions may differ only where H is within tol of tau
    diff = np.asarray(ex) != np.asarray(exr.astype(bool))
    assert np.all(np.abs(np.asarray(Hr)[diff] - tau) < 1e-2)


@pytest.mark.parametrize("B,T,H,K,chunk", [
    (2, 32, 2, 8, 8),
    (1, 50, 3, 16, 16),             # padding path
    (2, 64, 4, 32, 32),
])
def test_rwkv_wkv_sweep(B, T, H, K, chunk):
    r = jnp.array(RNG.normal(size=(B, T, H, K)), jnp.float32)
    k = jnp.array(RNG.normal(size=(B, T, H, K)), jnp.float32)
    v = jnp.array(RNG.normal(size=(B, T, H, K)), jnp.float32)
    lw = -jnp.array(RNG.uniform(0.05, 1.0, size=(B, T, H, K)), jnp.float32)
    u = jnp.array(RNG.normal(size=(H, K)), jnp.float32)
    y = rwkv_wkv(r, k, v, lw, u, chunk=chunk, interpret=True)

    def flat(x):
        return jnp.moveaxis(x, 2, 1).reshape(B * H, T, K)

    yr = rwkv_wkv_ref(flat(r), flat(k), flat(v), flat(lw),
                      jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, K))
    yr = jnp.moveaxis(yr.reshape(B, H, T, K), 1, 2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-4,
                               rtol=1e-3)


def test_rwkv_wkv_bf16_inputs():
    B, T, H, K = 1, 32, 2, 16
    r = jnp.array(RNG.normal(size=(B, T, H, K)), jnp.bfloat16)
    k = jnp.array(RNG.normal(size=(B, T, H, K)), jnp.bfloat16)
    v = jnp.array(RNG.normal(size=(B, T, H, K)), jnp.bfloat16)
    lw = -jnp.array(RNG.uniform(0.1, 1.0, size=(B, T, H, K)), jnp.float32)
    u = jnp.array(RNG.normal(size=(H, K)), jnp.float32)
    y = rwkv_wkv(r, k, v, lw, u, chunk=16, interpret=True)
    assert y.shape == (B, T, H, K)
    assert np.isfinite(np.asarray(y, np.float32)).all()
