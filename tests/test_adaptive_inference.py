"""Host-side adaptive router (``core.inference.AdaptiveInferenceEngine``,
paper Alg. 3): all-exit, none-exit, and pad-bucket remainder paths."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.inference import (H_CAP, AdaptiveInferenceEngine,
                                  exit_decision, paper_tau_to_entropy)

N_CLASSES = 8


def _logits(confident: np.ndarray) -> np.ndarray:
    """Per-row exit logits: sharp (low entropy, argmax = row % C) where
    ``confident``, uniform (H = ln C) elsewhere."""
    n = len(confident)
    out = np.zeros((n, N_CLASSES), np.float32)
    for i, c in enumerate(confident):
        if c:
            out[i, i % N_CLASSES] = 20.0
    return out


class _Counter:
    """server_fn stub: records call batch sizes, predicts class 7."""

    def __init__(self):
        self.batches = []

    def __call__(self, h):
        self.batches.append(int(h.shape[0]))
        out = np.zeros((h.shape[0], N_CLASSES), np.float32)
        out[:, 7] = 5.0
        return jnp.asarray(out)


def _engine(confident, tau=1.0, pad_bucket=8):
    conf = np.asarray(confident, bool)
    server = _Counter()
    eng = AdaptiveInferenceEngine(
        client_fn=lambda x: (x, jnp.asarray(_logits(conf))),
        server_fn=server, tau=tau, pad_bucket=pad_bucket)
    return eng, server, conf


def test_all_exit_never_calls_server():
    eng, server, conf = _engine([True] * 6)
    preds = eng(np.zeros((6, 4), np.float32))
    assert server.batches == []
    np.testing.assert_array_equal(preds, np.arange(6) % N_CLASSES)
    assert eng.stats.client_ratio == 1.0 and eng.stats.exited == 6


def test_none_exit_offloads_everything():
    eng, server, _ = _engine([False] * 5, pad_bucket=8)
    preds = eng(np.zeros((5, 4), np.float32))
    assert server.batches == [8]        # 5 requests padded to one bucket
    np.testing.assert_array_equal(preds, np.full(5, 7))
    assert eng.stats.client_ratio == 0.0
    # uniform logits: mean entropy is ln(C)
    assert eng.stats.mean_entropy == pytest.approx(np.log(N_CLASSES),
                                                   abs=1e-5)


def test_pad_bucket_remainder_mixed_batch():
    """11 offloads with bucket 4 -> server sees 12 rows, padding rows are
    discarded and exited rows keep their client predictions."""
    conf = np.arange(16) % 3 == 0       # 6 exit, 10 offload
    eng, server, _ = _engine(conf, pad_bucket=4)
    preds = eng(np.zeros((16, 4), np.float32))
    assert server.batches == [12]       # ceil(10 / 4) * 4
    np.testing.assert_array_equal(preds[conf], np.nonzero(conf)[0] % N_CLASSES)
    np.testing.assert_array_equal(preds[~conf], 7)
    assert eng.stats.exited == 6 and eng.stats.total == 16


def test_exact_bucket_multiple_is_not_padded():
    eng, server, _ = _engine([False] * 8, pad_bucket=4)
    eng(np.zeros((8, 4), np.float32))
    assert server.batches == [8]


def test_stats_accumulate_across_calls():
    eng, server, _ = _engine([True, False, True, False], pad_bucket=2)
    for _ in range(3):
        eng(np.zeros((4, 4), np.float32))
    assert eng.stats.total == 12 and eng.stats.exited == 6
    assert eng.stats.client_ratio == 0.5
    assert server.batches == [2, 2, 2]


def test_exit_decision_and_paper_tau_mapping():
    logits = jnp.asarray(_logits(np.array([True, False])))
    assert exit_decision(logits, 1.0).tolist() == [True, False]
    # conservativeness knob: tau_paper = H_CAP - tau_H (docs/DESIGN.md §1)
    assert paper_tau_to_entropy(0.0) == H_CAP
    assert paper_tau_to_entropy(H_CAP) == 0.0
