"""Fused vs. reference engine throughput (rounds/sec) on the Averaging
strategy — the headline metric for the scan+vmap engine (docs/ENGINES.md).

Both engines run behind ``repro.api.TrainSession`` (``engine="reference"``
vs ``engine="fused"``) on the same N-client MLP split workload and
identical data; the reference engine pays two jit dispatches plus a
``float(loss)`` host sync per client per minibatch, the fused engine runs
the whole chunk as one compiled scan.  Emits ``BENCH_fused.json`` with the
schema validated by ``tests/test_bench_smoke.py``.

  PYTHONPATH=src python -m benchmarks.fused_vs_reference
  PYTHONPATH=src python -m benchmarks.fused_vs_reference --rounds 200
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Sequence

import numpy as np

from repro.api import TrainSession
from repro.config import HeteroProfile, OptimizerConfig, SplitEEConfig
from repro.core.splitee import MLPSplitModel
from repro.data.pipeline import ClientPartitioner

SCHEMA_KEYS = ("benchmark", "config", "reference", "fused", "speedup",
               "max_metric_delta")


def _make_session(engine: str, splits: Sequence[int], parts, *,
                  batch_size: int, total_steps: int) -> TrainSession:
    model = MLPSplitModel(in_dim=32, hidden=64, num_classes=5, num_layers=4,
                          seed=0)
    return TrainSession.from_config(
        model,
        SplitEEConfig(profile=HeteroProfile(tuple(splits)),
                      strategy="averaging"),
        OptimizerConfig(lr=3e-3, total_steps=total_steps),
        parts, batch_size=batch_size, engine=engine)


def run(rounds: int = 60, clients: int = 4, batch_size: int = 64,
        local_epochs: int = 1, out: str = "BENCH_fused.json") -> List[Dict]:
    """Time both engines over ``rounds`` post-warmup rounds and write the
    comparison JSON.  Returns benchmark rows for benchmarks/run.py."""
    if rounds < 1 or clients < 1:
        raise ValueError(f"need rounds >= 1 and clients >= 1, "
                         f"got rounds={rounds} clients={clients}")
    splits = [1 + (i % 3) for i in range(clients)]         # hetero cuts 1/2/3
    rng = np.random.default_rng(0)
    classes, d = 5, 32
    centers = rng.normal(size=(classes, d)) * 2.0
    y = rng.integers(0, classes, 4096).astype(np.int32)
    x = (centers[y] + rng.normal(size=(4096, d))).astype(np.float32)
    parts = ClientPartitioner(clients, seed=0).split(x, y)
    total_steps = 4 * rounds * local_epochs + 16

    def time_engine(engine, **run_kw):
        sess = _make_session(engine, splits, parts, batch_size=batch_size,
                             total_steps=total_steps)
        sess.train(rounds, local_epochs, **run_kw)         # warmup + compile
        t0 = time.perf_counter()
        sess.train(rounds, local_epochs, **run_kw)
        wall = time.perf_counter() - t0
        return sess, wall

    ref_tr, ref_wall = time_engine("reference")
    fus_tr, fus_wall = time_engine("fused", chunk_rounds=rounds)

    # engines consumed identical data: timed-window metrics must agree
    deltas = [max(abs(a.client_loss - b.client_loss),
                  abs(a.server_loss - b.server_loss))
              for a, b in zip(ref_tr.history, fus_tr.history)]
    result = {
        "benchmark": "fused_vs_reference",
        "config": {"clients": clients, "splits": splits, "rounds": rounds,
                   "local_epochs": local_epochs, "batch_size": batch_size,
                   "strategy": "averaging", "model": "mlp-4x64"},
        "reference": {"wall_s": ref_wall,
                      "rounds_per_sec": rounds / ref_wall},
        "fused": {"wall_s": fus_wall, "rounds_per_sec": rounds / fus_wall},
        "speedup": ref_wall / fus_wall,
        "max_metric_delta": float(max(deltas)),
    }
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=1)

    return [{"name": f"fused_vs_reference/{eng}/N{clients}",
             "us_per_call": result[eng]["wall_s"] / rounds * 1e6,
             "derived": f"{result[eng]['rounds_per_sec']:.1f} rounds/s",
             **result} for eng in ("reference", "fused")]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-epochs", type=int, default=1)
    ap.add_argument("--out", default="BENCH_fused.json")
    args = ap.parse_args()
    rows = run(rounds=args.rounds, clients=args.clients,
               local_epochs=args.local_epochs, out=args.out)
    r = rows[0]
    print(f"reference: {r['reference']['rounds_per_sec']:.1f} rounds/s")
    print(f"fused    : {r['fused']['rounds_per_sec']:.1f} rounds/s")
    print(f"speedup  : {r['speedup']:.1f}x   "
          f"(max metric delta {r['max_metric_delta']:.2e})  -> {args.out}")


if __name__ == "__main__":
    main()
