"""Quickstart: Hetero-SplitEE in ~60 seconds on CPU.

Three heterogeneous clients (cut layers 1/2/3 of a 4-layer net) train one
shared model collaboratively with the Averaging strategy (paper Alg. 2),
then serve with the entropy-gated early exit (Alg. 3).

Training goes through ``repro.api.TrainSession`` — the one front door over
the engine registry (docs/API.md).  ``engine="auto"`` picks the widest
valid backend: the mesh-sharded spmd engine on a multi-device host, the
fused scan+vmap engine on this single-device demo (docs/ENGINES.md), the
paper-faithful reference engine for e.g. the Sequential strategy.  Pass
``engine="reference"`` to force the round-by-round oracle — both produce
the same numbers.  ``session.save(path)`` / ``TrainSession.restore(path,
model, clients)`` checkpoint and resume the full training state.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import TrainSession
from repro.config import HeteroProfile, OptimizerConfig, SplitEEConfig
from repro.core.splitee import MLPSplitModel
from repro.data.pipeline import ClientPartitioner


def main(rounds: int = 40, engine: str = "auto", log_every: int = 10):
    rng = np.random.default_rng(0)
    n, d, classes = 3000, 32, 5
    centers = rng.normal(size=(classes, d)) * 1.5
    y = rng.integers(0, classes, n).astype(np.int32)
    x = (centers[y] + rng.normal(size=(n, d))).astype(np.float32)
    train, test = (x[:2400], y[:2400]), (x[2400:], y[2400:])

    model = MLPSplitModel(in_dim=d, hidden=64, num_classes=classes,
                          num_layers=4, seed=0)
    profile = HeteroProfile(split_layers=(1, 2, 3))   # heterogeneous cuts
    clients = ClientPartitioner(3, seed=0).split(*train)

    session = TrainSession.from_config(
        model,
        SplitEEConfig(profile=profile, strategy="averaging"),
        OptimizerConfig(lr=3e-3, total_steps=60),
        clients, batch_size=64, engine=engine)
    print(f"engine: {session.engine_name}")
    session.train(rounds=rounds, local_epochs=1, log_every=log_every)

    ev = session.evaluate(*test)
    print("\nper-client accuracy (cut layers 1/2/3):")
    print("  client-side exits:", [f"{a:.3f}" for a in ev["client_acc"]])
    print("  server-side      :", [f"{a:.3f}" for a in ev["server_acc"]])

    print("\nadaptive inference (exit iff entropy < tau):")
    for tau in (0.1, 0.5, 1.0):
        ad = session.evaluate_adaptive(*test, tau=tau)
        print(f"  tau={tau:.1f}  acc={np.mean(ad['acc']):.3f}  "
              f"client-ratio={np.mean(ad['client_ratio']):.2f}")
    return session


if __name__ == "__main__":
    main()
