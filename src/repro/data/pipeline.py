"""Host-side data pipeline: IID client partitioning (paper §IV-A) and batch
iterators, including the group-contiguous global-batch assembly used by the
fused SPMD Hetero-SplitEE step (client group g owns slice g of the batch)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Sequence, Tuple

import numpy as np


@dataclass
class ClientPartitioner:
    """Uniform-at-random IID split of (x, y) across N clients.  The same
    partition (same seed) is reused by every strategy/baseline so that
    'observed performance differences isolate the effect of collaborative
    aggregation' (paper §IV-A4)."""

    num_clients: int
    seed: int = 0

    def split(self, x: np.ndarray, y: np.ndarray
              ) -> List[Tuple[np.ndarray, np.ndarray]]:
        rng = np.random.default_rng(self.seed)
        perm = rng.permutation(len(x))
        shards = np.array_split(perm, self.num_clients)
        return [(x[s], y[s]) for s in shards]


def batch_iterator(x: np.ndarray, y: np.ndarray, batch_size: int, *,
                   seed: int = 0, augment=None, epochs: int = 1_000_000
                   ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    n = len(x)
    bs = min(batch_size, n)         # tiny client shards: full-shard batches
    for _ in range(epochs):
        perm = rng.permutation(n)
        for i in range(0, n - bs + 1, bs):
            idx = perm[i : i + bs]
            bx = x[idx]
            if augment is not None:
                bx = augment(rng, bx)
            yield bx, y[idx]


def global_hetero_batch(client_batches: Sequence[Tuple[np.ndarray, np.ndarray]],
                        split_boundary_ids: Sequence[int]
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Assemble the fused-SPMD global batch: concatenate per-client batches in
    group order and emit the per-example split-boundary id vector."""
    xs = np.concatenate([b[0] for b in client_batches], axis=0)
    ys = np.concatenate([b[1] for b in client_batches], axis=0)
    ids = np.concatenate([
        np.full((len(b[0]),), sid, np.int32)
        for b, sid in zip(client_batches, split_boundary_ids)
    ])
    return xs, ys, ids
