"""Trip-count-aware HLO analysis for the roofline.

XLA's ``cost_analysis`` (and any naive text scan) counts a ``while`` body
ONCE, but our backbone drives layers through ``lax.scan`` — a 40-layer model
would be undercounted ~40x.  This module parses the post-SPMD HLO text,
recovers每 while loop's trip count from its condition computation
(``compare(iv, constant(N)), direction=LT``), builds the computation call
graph, and multiplies per-computation costs by the product of enclosing trip
counts.

Counted per computation (then scaled):
  * collective operand bytes by op kind (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute);
  * dot FLOPs: 2 x result_numel x contracted_size (the MXU term — the
    overwhelmingly dominant FLOPs in transformer workloads);
  * convolution FLOPs: 2 x result_numel x (kernel spatial x in_channels).

Validated by tests/test_hlo_analysis.py: a k-layer scan reports exactly k
times the one-layer cost.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that move no HBM bytes of their own (bookkeeping / aliasing)
_NO_TRAFFIC_OPS = frozenset({
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "domain", "opt-barrier",
})

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\-.]+)\s*\(")
_CALLED = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w\-.]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_bytes(m: re.Match) -> int:
    return _shape_elems(m.group(2)) * _DTYPE_BYTES[m.group(1)]


@dataclass
class Computation:
    name: str
    header: str = ""
    lines: List[str] = field(default_factory=list)
    # (callee, kind) — kind in {"body", "condition", "other"}
    calls: List[Tuple[str, str]] = field(default_factory=list)


def _split_computations(hlo: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = ""
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMP_HDR.match(s)
            if m and s.endswith("{") and " -> " in s:
                cur = Computation(m.group(1), header=s)
                if raw.startswith("ENTRY"):
                    entry = cur.name
            continue
        if s == "}":
            comps[cur.name] = cur
            cur = None
            continue
        if s.startswith("ROOT "):
            s = s[5:]
        cur.lines.append(s)
        for cm in re.finditer(r"(body|condition|to_apply|calls)=%?([\w\-.]+)", s):
            cur.calls.append((cm.group(2), cm.group(1)))
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Extract N from the while condition.  jax scans lower to
    ``compare(iv, constant(N)), direction=LT`` — possibly with the compare
    wrapped in a kLoop fusion, so we fall back to the largest integer
    constant defined in the condition computation."""
    const_by_name: Dict[str, int] = {}
    for s in cond.lines:
        m = re.match(r"%?([\w\-.]+)\s*=\s*\S+\s+constant\((\d+)\)", s)
        if m:
            const_by_name[m.group(1)] = int(m.group(2))
    for s in cond.lines:
        if "compare(" in s and "direction=LT" in s:
            for name, val in const_by_name.items():
                if name in s:
                    return val
            m = _CONST_RE.search(s)
            if m:
                return int(m.group(1))
    if const_by_name:
        return max(const_by_name.values())
    return 1


_DEF_RE = re.compile(r"^%?([\w\-.]+)\s*=\s*(.+)$")
_OPND_RE = re.compile(r"%([\w\-.]+)")
_PARAM_RE = re.compile(r"([\w\-.]+):\s*((?:\([^()]*\)|" + _SHAPE_RE.pattern
                       + r")[^,)]*)")


def _types_in(text: str):
    """All (bytes, shape_dims) of shape tokens in ``text``."""
    return [( _shape_bytes(m), m.group(2)) for m in _SHAPE_RE.finditer(text)]


def _symbol_table(comp: "Computation") -> Dict[str, Tuple[int, List[int]]]:
    """name -> (total bytes, first shape dims) for every instruction and
    header parameter of the computation."""
    table: Dict[str, Tuple[int, List[int]]] = {}

    def dims_of(text):
        m = _SHAPE_RE.search(text)
        if not m or not m.group(2):
            return []
        return [int(d) for d in m.group(2).split(",")]

    # header params
    hdr = comp.header
    body = hdr[hdr.find("(") + 1: hdr.rfind("->")]
    for pm in re.finditer(r"([\w\-.]+):\s*", body):
        name = pm.group(1)
        rest = body[pm.end():]
        # type runs until the matching comma at depth 0
        depth, end = 0, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = i
                    break
                depth -= 1
            elif ch == "," and depth == 0:
                end = i
                break
        t = rest[:end]
        table[name] = (sum(b for b, _ in _types_in(t)), dims_of(t))

    for s in comp.lines:
        dm = _DEF_RE.match(s)
        if not dm:
            continue
        rhs = dm.group(2)
        paren = rhs.find("(")
        tpart = rhs[:paren] if paren > 0 else rhs
        table[dm.group(1)] = (sum(b for b, _ in _types_in(tpart)),
                              dims_of(tpart))
    return table


def _line_cost(s: str, table: Dict[str, Tuple[int, List[int]]]):
    """Returns (kind, value): collective bytes or dot/conv flops, or None."""
    dm = _DEF_RE.match(s)
    if not dm:
        return None
    rhs = dm.group(2)
    opm = re.match(r"(?:\([^=]*?\)|\S+)\s+([\w\-]+)\(", rhs)
    if not opm:
        return None
    op = opm.group(1)
    paren = rhs.find(op + "(") + len(op)
    args_text = rhs[paren:]
    cut = args_text.find("),")
    operand_text = args_text[:cut if cut > 0 else len(args_text)]
    operands = _OPND_RE.findall(operand_text)

    res_bytes = table.get(dm.group(1), (0, []))[0]
    opnd_bytes = sum(table.get(o, (0, []))[0] for o in operands)
    cost = {}
    if op not in _NO_TRAFFIC_OPS:
        cost["bytes"] = float(res_bytes + opnd_bytes)

    for c in COLLECTIVES:
        if op == c or op == c + "-start":
            b = opnd_bytes
            if b == 0:        # fallback: result bytes
                b = sum(x for x, _ in _types_in(rhs[:rhs.find(op + "(")]))
            cost[c] = float(b)
            return cost

    if op == "dot" and operands:
        res_dims = table.get(dm.group(1), (0, []))[1]
        out_elems = 1
        for d in res_dims:
            out_elems *= d
        lhs_shape = table.get(operands[0], (0, []))[1]
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
        k = 1
        if cm and cm.group(1) and lhs_shape:
            for i in cm.group(1).split(","):
                idx = int(i)
                if idx < len(lhs_shape):
                    k *= lhs_shape[idx]
        cost["dot"] = 2.0 * out_elems * k

    if op == "convolution" and len(operands) >= 2:
        res_dims = table.get(dm.group(1), (0, []))[1]
        out_elems = 1
        for d in res_dims:
            out_elems *= d
        kdims = table.get(operands[1], (0, []))[1]
        if kdims:
            oc = kdims[-1]
            kn = 1
            for d in kdims:
                kn *= d
            cost["conv"] = 2.0 * out_elems * max(1, kn // max(1, oc))
    return cost or None


def analyze(hlo: str) -> Dict[str, object]:
    """Trip-count-aware totals over the whole module."""
    comps, entry = _split_computations(hlo)

    # computations reachable only through fusion calls must not contribute
    # "bytes" (their internals live in registers/VMEM, not HBM).
    fusion_only = set()
    referenced_as_body = set()
    for comp in comps.values():
        for callee, kind in comp.calls:
            if kind in ("body", "condition"):
                referenced_as_body.add(callee)
            else:
                fusion_only.add(callee)
    fusion_only -= referenced_as_body
    fusion_only.discard(entry)

    # per-computation local costs
    local: Dict[str, Dict[str, float]] = {}
    for name, comp in comps.items():
        acc: Dict[str, float] = {}
        table = _symbol_table(comp)
        for s in comp.lines:
            r = _line_cost(s, table)
            if r:
                for kk, vv in r.items():
                    if kk == "bytes" and name in fusion_only:
                        continue
                    acc[kk] = acc.get(kk, 0.0) + vv
        local[name] = acc

    # multiplier propagation from entry
    mult: Dict[str, float] = {}

    def visit(name: str, m: float):
        mult[name] = mult.get(name, 0.0) + m
        comp = comps.get(name)
        if comp is None:
            return
        trips: Dict[str, int] = {}
        # pair body/condition of the same while line
        for s in comp.lines:
            bm = re.search(r"body=%?([\w\-.]+)", s)
            cm = re.search(r"condition=%?([\w\-.]+)", s)
            if bm and cm:
                cond = comps.get(cm.group(1))
                trips[bm.group(1)] = _trip_count(cond) if cond else 1
        seen_other = set()
        for callee, kind in comp.calls:
            if kind == "body":
                visit(callee, m * trips.get(callee, 1))
            elif kind == "condition":
                visit(callee, m * (trips.get(callee, 1) + 1)
                      if False else m)   # condition runs trips+1 times; costs ~0
            elif callee not in seen_other:
                seen_other.add(callee)
                visit(callee, m)

    if entry:
        visit(entry, 1.0)
    else:                                  # fallback: flat
        for name in comps:
            mult[name] = 1.0

    totals: Dict[str, float] = {}
    for name, acc in local.items():
        m = mult.get(name, 0.0)
        for k, v in acc.items():
            totals[k] = totals.get(k, 0.0) + v * m

    coll = {c: totals.get(c, 0.0) for c in COLLECTIVES}
    return {
        "flops": totals.get("dot", 0.0) + totals.get("conv", 0.0),
        "dot_flops": totals.get("dot", 0.0),
        "hbm_bytes": totals.get("bytes", 0.0),
        "collective_bytes": coll,
        "collective_total": sum(coll.values()),
        "num_computations": len(comps),
    }
