"""SPMD engine: the fused round body staged under jit with recipe-driven
mesh shardings, as a pure ``TrainState -> TrainState`` executor (see
docs/ENGINES.md).

This is the scaling story for the Averaging/distributed strategies: the
chunk function the fused engine scans on one device is compiled with
explicit `jax.sharding.NamedSharding` constraints from a
``launch.shardings.ShardingRecipe`` — the SAME recipe machinery the offline
dry-run uses, so there is one sharding rule set in the repo, not two:

  * the **cohort carry** (stacked clients/servers, Adam moments, BN stats,
    every leaf ``[E, ...]``) is placed by
    ``launch.shardings.train_state_specs``: the lane dim shards over the
    mesh's ``"lanes"`` axis, remaining dims get the recipe's FSDP/TP rules
    (Adam moments mirroring their params), tiny leaves replicate;
  * the **pre-staged batches** (``[rounds, k, E, B, ...]`` per cohort)
    shard their lane dim over ``"lanes"`` and their per-lane batch dim
    ``B`` over the mesh's batch axes (``("pod", "data")`` where present),
    so each device receives only its lanes' slices;
  * XLA's SPMD partitioner inserts the per-minibatch gradient
    ``all-reduce`` over the batch axes, the FSDP ``all-gather`` /
    ``reduce-scatter`` around sharded params, and the cross-lane
    collectives for the in-graph Eq. (1) aggregation
    (``core.aggregation.stacked_cross_layer_aggregate`` sums over the lane
    dim, which is exactly a reduce over the ``"lanes"`` axis).

The math is byte-for-byte the fused engine's (the same
``core.spmd.make_cohort_train_step`` under the same scanned round body), so
spmd ``eq1`` is cross-checkable against the reference engine to float32
reduction tolerance — including ``aggregate_every`` boundaries, cross-recipe
checkpoint resume (states are saved as host arrays and re-placed through
whatever recipe the restoring session runs), and spmd<->fused hand-offs
(tests/test_spmd_engine.py).

Meshes: pass one explicitly (``TrainSession(..., mesh=...)`` — e.g.
``launch.mesh.make_production_mesh(lanes=4)`` or
``launch.mesh.make_host_mesh((2, 2, 1), ("lanes", "data", "model"))``) or
let the engine build the default data-parallel mesh over every visible
device.  Recipes: ``TrainSession(..., recipe=...)`` — a name from
``launch.shardings.NAMED_RECIPES`` (``"greedy"`` default, ``"megatron"``,
``"fsdp-off"``, ``"replicate"``, ...) or a ``ShardingRecipe`` instance.  On
a CPU container, expose fake devices first:
``XLA_FLAGS=--xla_force_host_platform_device_count=4``.

**Multi-host**: after ``jax.distributed.initialize`` (see
``launch.distributed`` / ``launch.train --distributed``) ``jax.devices()``
is the *global* device list, so the default data mesh — and any
``launch.mesh`` helper — spans every process.  Every process draws the
identical seeded batch stream (the data layer is deterministic, so no
cross-host data exchange is needed) and the engine assembles global
arrays from the host-replicated staging buffers via
``jax.make_array_from_process_local_data``: each process extracts and
uploads only the shard rows its local devices own.  The carry is placed
the same way, and fetched back through a replicating reshard so the
returned ``TrainState`` holds host arrays on every process
(tests/test_distributed.py asserts 2-process ≡ 1-process parity).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.api.engines import (SessionContext, cohort_layout,
                               register_engine)
from repro.api.fused_engine import FusedEngine
from repro.core.splitee import stack_pytrees
from repro.data.pipeline import effective_batch_size
from repro.launch.mesh import axis_sizes, batch_axes, lane_axis
from repro.launch.shardings import (resolve_recipe, stage_batch_spec,
                                    to_named, train_state_specs)
from repro.optim import adam_init


def default_data_mesh():
    """A 1-D data-parallel mesh over every visible device (the host-CPU
    test topology and the single-process accelerator default).  Production
    launches pass ``launch.mesh.make_production_mesh()`` instead."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def resolve_mesh(ctx: SessionContext):
    """The mesh this session's spmd engine runs on: the explicit
    ``ctx.mesh`` when one was supplied, else the default data mesh."""
    return ctx.mesh if ctx.mesh is not None else default_data_mesh()


def data_parallelism(mesh) -> int:
    """Total batch-axis parallelism of ``mesh`` (product of the ``pod`` and
    ``data`` axis sizes present)."""
    sizes = axis_sizes(mesh)
    return int(np.prod([sizes[a] for a in batch_axes(mesh)]))


def abstract_cohort_carry(model, split_layers, opt_cfg):
    """The engines' cohort scan carry as a ``jax.eval_shape`` pytree:
    ``{li: (client, client_opt, server, server_opt)}`` with every leaf
    stacked along a leading lane dim.  ``model`` may be a ``SplitModel``
    adapter or a zero-arg factory returning one — the factory runs under
    abstract evaluation, so no parameters materialize (the recipe
    conformance tests build full-arch carries this way)."""
    lis, lanes = cohort_layout(split_layers)

    def build():
        m = model() if callable(model) else model
        carry = {}
        for li in lis:
            cs = [m.make_client(li) for _ in lanes[li]]
            ss = [m.make_server(li) for _ in lanes[li]]
            carry[li] = (
                m.stack_clients(cs),
                stack_pytrees([adam_init(c["trainable"], opt_cfg)
                               for c in cs]),
                m.stack_clients(ss),
                stack_pytrees([adam_init(s["trainable"], opt_cfg)
                               for s in ss]),
            )
        return carry

    return jax.eval_shape(build)


def _model_num_experts(model) -> int:
    """Expert count for the recipe's expert-parallel rules, when the
    adapter wraps a MoE backbone config."""
    cfg = getattr(model, "cfg", None)
    moe = getattr(cfg, "moe", None)
    return int(moe.num_experts) if moe is not None else -1


@register_engine("spmd")
class SpmdEngine(FusedEngine):
    """Recipe-driven mesh-sharded execution of the fused scan+vmap round
    body."""

    def __init__(self, ctx: SessionContext):
        super().__init__(ctx)
        self.mesh = resolve_mesh(ctx)
        self.recipe = resolve_recipe(ctx.recipe)
        self._replicated = NamedSharding(self.mesh, P())

        # recipe shardings for the carry, from its abstract shapes (built
        # once — the carry structure is fixed by the immutable context)
        carry = abstract_cohort_carry(ctx.model, ctx.profile.split_layers,
                                      ctx.opt_cfg)
        self._carry_specs = train_state_specs(
            self.recipe, self.mesh, carry,
            num_experts=_model_num_experts(ctx.model))
        self._carry_shardings = to_named(self._carry_specs, self.mesh)

        # per-cohort staged-batch shardings ([rounds, k, E, B, ...])
        self._batch_shardings: Dict[int, NamedSharding] = {}
        for li in self._cohort_lis:
            i0 = self._lanes[li][0]
            eb = effective_batch_size(len(ctx.client_data[i0][0]),
                                      ctx.batch_size)
            self._batch_shardings[li] = NamedSharding(
                self.mesh, stage_batch_spec(self.recipe, self.mesh,
                                            self._counts[li], eb))

    @classmethod
    def supports(cls, ctx: SessionContext) -> Optional[str]:
        reason = super().supports(ctx)           # strategy + ragged cohorts
        if reason:
            return reason
        if ctx.mesh is None and len(jax.devices()) < 2:
            return ("needs a mesh (TrainSession(..., mesh=...)) or >1 "
                    "visible device (e.g. XLA_FLAGS=--xla_force_host_"
                    "platform_device_count=4); only 1 device visible")
        mesh = resolve_mesh(ctx)
        recipe = resolve_recipe(ctx.recipe)
        sizes = axis_sizes(mesh)
        dp = data_parallelism(mesh)
        lax_name = lane_axis(mesh)
        lane_sz = (sizes.get(lax_name, 1)
                   if lax_name and recipe.shard_lanes else 1)
        if dp < 2 and lane_sz < 2:
            if lax_name and sizes.get(lax_name, 1) > 1:
                return (f"mesh {sizes} only has parallelism on its lanes "
                        f"axis, which recipe {ctx.recipe_name!r} disables "
                        f"(shard_lanes=False); pick a lane-sharding recipe "
                        f"or a mesh with batch-axis parallelism")
            return (f"mesh {sizes} has no parallelism on its batch axes "
                    f"{batch_axes(mesh)} or a lanes axis")
        for i, (xd, _) in enumerate(ctx.client_data):
            eb = effective_batch_size(len(xd), ctx.batch_size)
            if dp > 1 and eb % dp != 0:
                return (f"client {i}'s effective batch size {eb} does not "
                        f"divide over the data-parallel size {dp}; adjust "
                        f"batch_size or the mesh")
        if lane_sz > 1:
            _, lanes = cohort_layout(ctx.profile.split_layers)
            counts = {li: len(v) for li, v in lanes.items()}
            if not any(c % lane_sz == 0 for c in counts.values()):
                return (f"the mesh's {lane_sz}-way lanes axis divides no "
                        f"cohort's lane count {counts}; equalize cohort "
                        f"sizes, shrink the lanes axis, or use a mesh "
                        f"without one")
        return None

    # ------------------------------------------------------------- staging
    def _compile_chunk(self, chunk: Callable) -> Callable:
        """Jit the scanned round body with the recipe's shardings: the
        carry (params / moments / BN stats) placed per-leaf by
        ``train_state_specs``, staged batch tensors per-cohort by
        ``stage_batch_spec``, per-round losses replicated.  The carry is
        still donated, so long chunks run in place."""
        bsh = dict(self._batch_shardings)
        return jax.jit(chunk,
                       in_shardings=(self._carry_shardings,
                                     self._replicated, bsh, dict(bsh)),
                       out_shardings=(self._carry_shardings,
                                      (self._replicated, self._replicated)),
                       donate_argnums=(0,))

    def _put_global(self, arr, sharding: NamedSharding):
        """Host array -> a (possibly process-spanning) ``sharding``.

        Single-process: a plain ``device_put``.  Multi-process: every
        process holds the identical full host copy (the data layer's
        seeded draws and the host-side carry stacking are deterministic),
        so ``jax.make_array_from_process_local_data`` with
        ``global_shape == arr.shape`` lets each process extract and
        upload exactly the shard rows its local devices own — no
        cross-host data exchange."""
        if jax.process_count() == 1:
            return jax.device_put(arr, sharding)
        arr = np.asarray(arr)
        return jax.make_array_from_process_local_data(
            sharding, arr, global_shape=arr.shape)

    def _put_batch(self, arr, li: int):
        """Host-staged batch numpy -> its cohort's sharding directly, so
        each device receives only its lanes' and batch rows' slices (never
        materializing the whole chunk on one device)."""
        return self._put_global(arr, self._batch_shardings[li])

    def _put_ts(self, t: int, n: int):
        ts = np.arange(t, t + n, dtype=np.int32)
        return self._put_global(ts, self._replicated)

    def _stack_carry(self, clients, copts, servers, sopts):
        """Place the stacked carry into its recipe shardings up front
        (avoids an implicit single-device -> sharded reshard inside the
        jit and keeps donation effective).  Multi-process runs place each
        leaf from its host-replicated copy, like the batches."""
        carry = super()._stack_carry(clients, copts, servers, sopts)
        if jax.process_count() == 1:
            return jax.device_put(carry, self._carry_shardings)
        return jax.tree.map(self._put_global, carry, self._carry_shardings)

    def _fetch_carry(self, carry):
        """Multi-process carries have non-addressable shards, so the
        run-final carry is resharded to fully-replicated (an in-graph
        cross-host all-gather) and pulled to host numpy — reading one
        addressable shard of a replicated array is the whole value —
        before the engine unstacks per-client states.  Single-process
        carries are already fully addressable: no copy."""
        if jax.process_count() == 1:
            return carry
        replicate = jax.jit(
            lambda c: c,
            out_shardings=jax.tree.map(lambda _: self._replicated, carry))
        return jax.tree.map(lambda a: np.asarray(a.addressable_data(0)),
                            replicate(carry))

    def _host_losses(self, closs, sloss):
        if jax.process_count() == 1:
            return super()._host_losses(closs, sloss)
        return (np.asarray(closs.addressable_data(0)),
                np.asarray(sloss.addressable_data(0)))
