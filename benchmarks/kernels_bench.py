"""Kernel parity + latency micro-bench.  On this CPU container the Pallas
kernels run in interpret mode, so wall-times are NOT TPU estimates — the
benchmark's purpose is (a) parity vs the jnp oracle on bench-scale shapes and
(b) a regression guard on call overhead."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import entropy_exit, flash_attention, rwkv_wkv
from repro.kernels.ref import (entropy_exit_ref, flash_attention_ref,
                               rwkv_wkv_ref)


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)                     # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> List[dict]:
    rng = np.random.default_rng(0)
    rows = []

    q = jnp.array(rng.normal(size=(1, 4, 128, 64)), jnp.float32)
    k = jnp.array(rng.normal(size=(1, 2, 128, 64)), jnp.float32)
    v = jnp.array(rng.normal(size=(1, 2, 128, 64)), jnp.float32)
    t = _time(flash_attention, q, k, v, interpret=True)
    err = float(jnp.abs(flash_attention(q, k, v, interpret=True)
                        - flash_attention_ref(q, k, v)).max())
    rows.append({"table": "kernels", "name": "flash_attention_128",
                 "us_per_call": round(t, 1), "max_err": err})

    x = jnp.array(rng.normal(size=(32, 8192)) * 2, jnp.float32)
    t = _time(entropy_exit, x, 1.5, interpret=True)
    H, _ = entropy_exit(x, 1.5, interpret=True)
    Hr, _ = entropy_exit_ref(x, 1.5)
    rows.append({"table": "kernels", "name": "entropy_exit_8k",
                 "us_per_call": round(t, 1),
                 "max_err": float(jnp.abs(H - Hr).max())})

    r = jnp.array(rng.normal(size=(2, 128, 4, 32)), jnp.float32)
    kk = jnp.array(rng.normal(size=(2, 128, 4, 32)), jnp.float32)
    vv = jnp.array(rng.normal(size=(2, 128, 4, 32)), jnp.float32)
    lw = -jnp.array(rng.uniform(0.05, 1.0, size=(2, 128, 4, 32)), jnp.float32)
    u = jnp.array(rng.normal(size=(4, 32)), jnp.float32)
    t = _time(rwkv_wkv, r, kk, vv, lw, u, interpret=True)
    y = rwkv_wkv(r, kk, vv, lw, u, interpret=True)

    def flat(x):
        return jnp.moveaxis(x, 2, 1).reshape(8, 128, 32)

    yr = rwkv_wkv_ref(flat(r), flat(kk), flat(vv), flat(lw),
                      jnp.broadcast_to(u[None], (2, 4, 32)).reshape(8, 32))
    yr = jnp.moveaxis(yr.reshape(2, 4, 128, 32), 1, 2)
    rows.append({"table": "kernels", "name": "rwkv_wkv_128",
                 "us_per_call": round(t, 1),
                 "max_err": float(jnp.abs(y - yr).max())})
    return rows
