"""command-r-35b [dense] — 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000, GQA, no biases.  [hf:CohereForAI/c4ai-command-r-v01]"""
from __future__ import annotations

from repro.config import HeteroProfile, ModelConfig

EXITS = (10, 20, 30)


def config(sliding_window=None) -> ModelConfig:
    return ModelConfig(
        name="command-r-35b", arch_type="dense",
        num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=22528, vocab_size=256000, head_dim=128,
        rope_theta=10000.0, act="silu", exit_layers=EXITS,
        sliding_window=sliding_window,
        source="hf:CohereForAI/c4ai-command-r-v01",
    )


def smoke() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name="command-r-35b-smoke", arch_type="dense",
        num_layers=4, d_model=256, num_heads=8, num_kv_heads=2,
        d_ff=512, vocab_size=512, head_dim=32, exit_layers=(1, 2),
        dtype=jnp.float32, param_dtype=jnp.float32,
        source="hf:CohereForAI/c4ai-command-r-v01",
    )


def profile() -> HeteroProfile:
    return HeteroProfile(split_layers=(EXITS[0],) * 4 + (EXITS[1],) * 4
                         + (EXITS[2],) * 4)
