"""Mesh construction and axis queries.

``make_production_mesh`` is a FUNCTION (module import never touches jax
device state).  Target: TPU v5e, 16x16 = 256 chips per pod; the multi-pod
configuration stacks 2 pods (512 chips) behind a leading "pod" axis used for
data parallelism across the DCN/ICI boundary.

Axes the rest of the stack understands:

  * ``"pod"``   — optional leading data-parallel axis across pods;
  * ``"lanes"`` — optional cohort-lane axis: the fused/spmd engines stack
    clients sharing a split layer along a leading lane dimension, and a
    mesh with a ``lanes`` axis shards that dimension (each device holds
    only its lanes' client/server replicas, Adam moments, and batch
    slices) instead of replicating the whole cohort;
  * ``"data"``  — per-lane batch parallelism;
  * ``"model"`` — tensor parallelism (``launch/shardings.py`` recipes).

``MeshSpec`` is a device-free mesh description: ``axis_sizes`` /
``batch_axes`` / ``lane_axis`` accept either a live ``jax`` mesh or a
``MeshSpec``, so sharding recipes can be computed and validated (e.g. the
conformance tests over every registered arch) without faking devices.

Every helper builds over ``jax.devices()`` — which, after
``jax.distributed.initialize`` (``launch.distributed``), is the *global*
device list across all processes: the same ``make_lane_host_mesh(2)``
call yields a process-spanning mesh on a 2-host launch with no code
change (the spmd engine places process-local shards into it via
``jax.make_array_from_process_local_data``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax

#: the cohort-lane mesh axis name (see launch/shardings.py recipes)
LANE_AXIS = "lanes"


@dataclass(frozen=True)
class MeshSpec:
    """Named axis sizes without devices — enough to compute and validate
    PartitionSpec trees (``launch.shardings.train_state_specs``) off any
    topology, including ones larger than the running host."""

    axis_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]

    def __post_init__(self):
        if len(self.axis_shape) != len(self.axis_names):
            raise ValueError(f"MeshSpec shape {self.axis_shape} does not "
                             f"match axes {self.axis_names}")

    @property
    def shape(self) -> dict:
        return dict(zip(self.axis_names, self.axis_shape))


def make_production_mesh(*, multi_pod: bool = False, lanes: int = 1):
    """The 256-chip (single-pod) / 512-chip (multi-pod) production mesh.
    ``lanes > 1`` factors a leading cohort-lane axis out of the 16-wide
    data axis (total chip count unchanged)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if lanes > 1:
        data = shape[-2]
        if data % lanes:
            raise ValueError(f"lanes={lanes} does not divide the data axis "
                             f"({data} chips); pick a divisor of {data}")
        shape = shape[:-2] + (lanes, data // lanes, shape[-1])
        axes = axes[:-2] + (LANE_AXIS, "data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape: Tuple[int, ...] = (2, 2),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh over host CPU devices for tests/examples — e.g.
    ``make_host_mesh((2, 2, 1), ("lanes", "data", "model"))`` on a 4-device
    host splits cohort lanes over two devices and each lane's batch over
    the other two."""
    return jax.make_mesh(shape, axes)


def make_lane_host_mesh(lanes: int, devices: Optional[int] = None):
    """The canonical ``(lanes, n/lanes, 1)`` lanes/data/model mesh over the
    host's devices (every visible one unless ``devices`` caps it): cohort
    lanes over the leading axis, each lane's batch over the rest."""
    n = devices if devices is not None else len(jax.devices())
    if lanes < 1 or n % lanes:
        raise ValueError(f"lanes={lanes} does not divide the {n} devices")
    return make_host_mesh((lanes, n // lanes, 1),
                          (LANE_AXIS, "data", "model"))


def axis_sizes(mesh) -> dict:
    """``{axis name: size}`` for a live mesh or a :class:`MeshSpec`."""
    return dict(mesh.shape)


def batch_axes(mesh) -> Tuple[str, ...]:
    """The mesh axes a (per-lane) global batch shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def lane_axis(mesh) -> Optional[str]:
    """The cohort-lane axis name if the mesh has one, else ``None``."""
    return LANE_AXIS if LANE_AXIS in mesh.axis_names else None
