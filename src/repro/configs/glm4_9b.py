"""glm4-9b [dense] — 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552, RoPE + GQA.  [hf:THUDM/glm-4-9b]"""
from __future__ import annotations

from repro.config import HeteroProfile, ModelConfig

EXITS = (10, 20, 30)


def config(sliding_window=None) -> ModelConfig:
    return ModelConfig(
        name="glm4-9b", arch_type="dense",
        num_layers=40, d_model=4096, num_heads=32, num_kv_heads=2,
        d_ff=13696, vocab_size=151552, head_dim=128,
        rope_theta=10000.0, act="silu", exit_layers=EXITS,
        sliding_window=sliding_window,
        source="hf:THUDM/glm-4-9b",
    )


def smoke() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name="glm4-9b-smoke", arch_type="dense",
        num_layers=4, d_model=256, num_heads=8, num_kv_heads=2,
        d_ff=512, vocab_size=512, head_dim=32, exit_layers=(1, 2),
        dtype=jnp.float32, param_dtype=jnp.float32,
        source="hf:THUDM/glm-4-9b",
    )


def profile() -> HeteroProfile:
    return HeteroProfile(split_layers=(EXITS[0],) * 4 + (EXITS[1],) * 4
                         + (EXITS[2],) * 4)
