"""jit'd public wrappers around the Pallas kernels: shape padding to block
multiples, dtype handling, and an ``interpret`` switch that defaults to True
off-TPU (this container) and False on real TPU."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.entropy_exit import entropy_exit_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rwkv_wkv import rwkv_wkv_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    kv_valid=None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """q: (B, H, Tq, D); k/v: (B, Hkv, Tk, D).  Arbitrary Tq/Tk (padded).
    ``kv_valid`` is an optional traced int32 scalar: keys at
    ``kpos >= kv_valid`` are masked (the decode ring-buffer valid prefix);
    it varies per call without triggering recompilation."""
    interpret = _default_interpret() if interpret is None else interpret
    Tq, Tk = q.shape[2], k.shape[2]
    bq, bk = min(block_q, max(Tq, 8)), min(block_k, max(Tk, 8))
    qp, pq = _pad_to(q, 2, bq)
    kp, _ = _pad_to(k, 2, bk)
    vp, _ = _pad_to(v, 2, bk)
    # the kernel masks padded keys (kpos >= Tk) explicitly, so any
    # causal/window/ragged (Tq != Tk) combination is safe; padded q rows are
    # garbage but sliced off below
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 block_q=bq, block_k=bk, seq_k=Tk,
                                 kv_len=kv_valid, interpret=interpret)
    return out[:, :, :Tq]


@functools.partial(jax.jit, static_argnames=("block_rows", "block_v",
                                             "interpret"))
def entropy_exit(logits, tau, *, block_rows: int = 8,
                 block_v: int = 2048, interpret: Optional[bool] = None):
    """logits (B, V) -> (entropy (B,), exit_mask (B,) bool).  ``tau`` is a
    traced runtime scalar (float or 0-d array): threshold sweeps reuse one
    compilation, matching ``make_serve_step``'s traced-tau contract."""
    interpret = _default_interpret() if interpret is None else interpret
    B, V = logits.shape
    br = min(block_rows, B) if B % min(block_rows, B) == 0 else 1
    xp, pb = _pad_to(logits, 0, br)
    bv = min(block_v, max(128, V))
    H, ex = entropy_exit_pallas(xp, tau, block_rows=br, block_v=bv,
                                interpret=interpret)
    return H[:B], ex[:B].astype(bool)


@functools.partial(jax.jit, static_argnames=("chunk", "return_state",
                                             "interpret"))
def rwkv_wkv(r, k, v, log_w, u, *, chunk: int = 64,
             return_state: bool = False,
             interpret: Optional[bool] = None):
    """r/k/v/log_w: (B, T, H, K); u: (H, K) -> y (B, T, H, K) fp32.
    Arbitrary T (padded; log_w pads to 0 => identity steps).  With
    ``return_state`` also returns the final carried state (B, H, K, K) fp32
    (unaffected by padding: pad steps have decay 1 and k = 0)."""
    interpret = _default_interpret() if interpret is None else interpret
    B, T, H, K = r.shape
    ch = min(chunk, T)

    def flat(x):
        return jnp.moveaxis(x, 2, 1).reshape(B * H, T, K)

    rf, kf, vf, lwf = flat(r), flat(k), flat(v), flat(log_w)
    rf, _ = _pad_to(rf, 1, ch)
    kf, _ = _pad_to(kf, 1, ch)
    vf, _ = _pad_to(vf, 1, ch)
    lwf, _ = _pad_to(lwf, 1, ch)
    uf = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, K)
    y, sT = rwkv_wkv_pallas(rf, kf, vf, lwf, uf, chunk=ch,
                            interpret=interpret)
    y = y[:, :T].reshape(B, H, T, K)
    y = jnp.moveaxis(y, 1, 2)
    if return_state:
        return y, sT.reshape(B, H, K, K)
    return y
