"""Generate docs/EXPERIMENTS.md §Dry-run and §Roofline sections from the dry-run
artifacts.  Usage:

  PYTHONPATH=src python -m benchmarks.report \
      experiments/artifacts/dryrun_baseline.jsonl >> docs/EXPERIMENTS.md
"""
from __future__ import annotations

import json
import sys

from benchmarks.roofline import CHIPS, MOVE_HINTS, load, terms
from repro.config import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

V5E_HBM_GB = 16.0


def dryrun_section(rows):
    out = ["\n## §Dry-run\n",
           "Every (architecture × input shape) lowered **and compiled** with "
           "`jax.jit(...).lower().compile()` on the production meshes "
           "(16×16=256 chips single-pod; 2×16×16=512 chips multi-pod), "
           "XLA SPMD over 512 host placeholder devices.  Collective bytes "
           "are trip-count-aware per-device totals (scan bodies expanded).\n",
           "| arch | shape | mesh | status | compile s | peak mem/dev GB | "
           "fits v5e? | collective bytes/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"skipped ({r['reason'][:40]}…) | — | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"ERROR | — | — | — | — |")
            continue
        peak = r["memory"].get("peak_memory_bytes", 0) / 2**30
        fits = "yes" if peak <= V5E_HBM_GB else f"NO ({peak:.0f} GB)"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r.get('compile_s', 0):.0f} | {peak:.2f} | {fits} | "
            f"{r['analysis']['collective_total_per_device']:.2e} |")
    return "\n".join(out)


def roofline_section(rows, mesh="single_pod"):
    out = [f"\n## §Roofline ({mesh}, {CHIPS[mesh]} chips, per-step seconds)\n",
           "Terms: compute = HLO_FLOPs/dev ÷ 197 TF/s; memory = HLO bytes/dev"
           " ÷ 819 GB/s (instruction-level operand+result traffic — an "
           "UNFUSED upper bound on HBM traffic, comparable across recipes); "
           "collective = collective bytes/dev ÷ 50 GB/s/link.  "
           "MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference); "
           "useful ratio = MODEL_FLOPS ÷ (HLO_FLOPs × chips).\n",
           "| arch | shape | compute s | memory s | collective s | dominant |"
           " useful ratio | what moves the dominant term |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        if r.get("mesh") != mesh:
            continue
        t = terms(r)
        if t is None:
            if r.get("status") == "skipped":
                out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                           f"skipped | — | {r.get('reason','')[:60]} |")
            continue
        out.append(
            f"| {t['arch']} | {t['shape']} | {t['compute_s']:.3g} | "
            f"{t['memory_s']:.3g} | {t['collective_s']:.3g} | "
            f"**{t['dominant']}** | {t['useful_ratio']:.3f} | "
            f"{MOVE_HINTS[t['dominant']]} |")
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else \
        "experiments/artifacts/dryrun_baseline.jsonl"
    rows = load(path)
    print(dryrun_section(rows))
    print(roofline_section(rows, "single_pod"))


if __name__ == "__main__":
    main()
