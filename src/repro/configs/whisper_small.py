"""whisper-small [audio] — enc-dec; we implement the 12L **decoder**
(d_model=768, 12H kv=12, d_ff=3072, vocab=51865, GeLU, biases) with cross
attention over stubbed encoder states (1500 frames of 768-dim embeddings —
the conv/mel frontend and the encoder itself are the allowed stub, see
docs/DESIGN.md §4).  Deviation: RoPE replaces Whisper's learned absolute
positions (TPU-idiomatic; does not affect split/exit semantics).
[arXiv:2212.04356]"""
from __future__ import annotations

from repro.config import HeteroProfile, ModelConfig

EXITS = (3, 6, 9)


def config(sliding_window=None) -> ModelConfig:
    return ModelConfig(
        name="whisper-small", arch_type="audio",
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
        d_ff=3072, vocab_size=51865, head_dim=64,
        act="gelu", use_qkv_bias=True, use_mlp_bias=True,
        cross_attention=True, cross_source_len=1500,
        exit_layers=EXITS, sliding_window=sliding_window,
        source="arXiv:2212.04356",
    )


def smoke() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name="whisper-small-smoke", arch_type="audio",
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512, head_dim=32,
        act="gelu", use_qkv_bias=True, use_mlp_bias=True,
        cross_attention=True, cross_source_len=16,
        exit_layers=(2,), dtype=jnp.float32, param_dtype=jnp.float32,
        source="arXiv:2212.04356",
    )


def profile() -> HeteroProfile:
    return HeteroProfile(split_layers=(EXITS[0],) * 4 + (EXITS[1],) * 4
                         + (EXITS[2],) * 4)
