"""Kernel-backend dispatch: routes the model-layer hot sites — GQA/cross
attention, the RWKV6 wkv recurrence, and the Alg.-3 entropy gate — to either
the Pallas kernels (``repro.kernels.ops``) or the pure-XLA reference code
they were validated against.

The knob is ``ModelConfig.kernels`` in ``{"auto", "pallas", "ref"}``:

  * ``"ref"``    — the pure-jnp code paths the repo always ran (``_sdpa`` +
    ``causal_mask``, ``ssm._wkv_chunked``, ``losses.softmax_entropy``).
    Character-identical to the pre-dispatch behaviour.
  * ``"pallas"`` — the fused kernels.  On TPU they compile natively; on any
    other backend they run in Pallas **interpret mode**, which executes the
    same kernel program through XLA ops — slow, but numerically faithful,
    which is what makes off-TPU CI a real parity oracle (docs/DESIGN.md).
  * ``"auto"``   — ``"pallas"`` iff ``jax.default_backend() == "tpu"``,
    else ``"ref"``.  Default: CPU test runs stay bit-identical to the
    reference while TPU runs get the fused kernels.

Backend contract (:class:`KernelBackend`): all three methods take *model*
layouts (the shapes the call sites already hold), return the same dtypes the
reference path returned, and must agree with the reference within the
per-site tolerances documented in docs/ENGINES.md.  Training sites need
gradients; Pallas kernels have no autodiff rule, so the pallas backend wraps
them in ``jax.custom_vjp``: Pallas forward, backward = the VJP of the
matching ``repro.kernels.ref`` oracle (a recompute — the fwd/bwd pair stays
within the fwd parity tolerance of the all-reference gradient).  Decode-path
calls (traced ``kv_valid``) never differentiate and skip the wrapper.

Third-party backends can be added with :func:`register_backend`.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels import ref as kref

KERNEL_CHOICES = ("auto", "pallas", "ref")


def resolve_kernels(name: str = "auto", platform: Optional[str] = None) -> str:
    """Resolve the config knob to a registered backend name.  ``"auto"`` is
    ``"pallas"`` on TPU (native compile) and ``"ref"`` everywhere else;
    ``platform`` overrides the detected ``jax.default_backend()`` (the
    roofline report resolves for ``"tpu"`` regardless of the host)."""
    if name != "auto" and name not in _BACKENDS:
        raise ValueError(f"unknown kernels backend {name!r}; expected one of "
                         f"{('auto',) + available_backends()}")
    if name != "auto":
        return name
    platform = jax.default_backend() if platform is None else platform
    return "pallas" if platform == "tpu" else "ref"


# ---------------------------------------------------------------------------
# the backend interface
# ---------------------------------------------------------------------------


class KernelBackend:
    """One implementation of the three routed hot sites (model layouts)."""

    name = "base"

    def attention(self, q, k, v, *, causal: bool = False,
                  window: Optional[int] = None, kv_valid=None):
        """q (B,T,H,hd), k/v (B,S,Hkv,hd), H % Hkv == 0 -> (B,T,H,hd).
        ``causal``/``window`` are the static train/prefill masks;
        ``kv_valid`` is the traced decode ring-buffer valid prefix
        (keys at ``kpos >= kv_valid`` are masked)."""
        raise NotImplementedError

    def wkv(self, r, k, v, log_w, u, *, chunk: int):
        """RWKV6 wkv.  r/k/v/log_w (B,T,H,K), u (H,K) ->
        ``(y (B,T,H,K) fp32, S_T (B,H,K,K) fp32)``."""
        raise NotImplementedError

    def entropy_gate(self, logits, tau):
        """logits (..., V), traced scalar ``tau`` ->
        ``(H (...) fp32, exit (...) bool)`` with exit iff ``H < tau``."""
        raise NotImplementedError


class ReferenceBackend(KernelBackend):
    """The pure-XLA paths the call sites always ran — character-identical
    math, so ``kernels="ref"`` reproduces pre-dispatch behaviour bitwise."""

    name = "ref"

    def attention(self, q, k, v, *, causal: bool = False,
                  window: Optional[int] = None, kv_valid=None):
        from repro.models.attention import _sdpa, causal_mask
        T, S = q.shape[1], k.shape[1]
        scale = 1.0 / math.sqrt(q.shape[-1])
        mask = None
        if causal:
            mask = causal_mask(T, S, window)
        if kv_valid is not None:
            valid = (jnp.arange(S) < kv_valid)[None, :]
            mask = valid if mask is None else mask & valid
        return _sdpa(q, k, v, mask, scale)

    def wkv(self, r, k, v, log_w, u, *, chunk: int):
        from repro.models.ssm import _wkv_chunked
        return _wkv_chunked(r, k, v, log_w, u, chunk)

    def entropy_gate(self, logits, tau):
        from repro.core.losses import softmax_entropy
        H = softmax_entropy(logits)
        return H, H < tau


class PallasBackend(KernelBackend):
    """The fused kernels (``repro.kernels.ops``): native on TPU, interpret
    mode elsewhere.  Training sites differentiate through ``custom_vjp``
    wrappers whose backward recomputes via the ``kernels/ref`` oracles."""

    name = "pallas"

    def attention(self, q, k, v, *, causal: bool = False,
                  window: Optional[int] = None, kv_valid=None):
        qt, kt, vt = (jnp.swapaxes(a, 1, 2) for a in (q, k, v))
        if kv_valid is None:
            out = _diff_flash_attention(causal, window)(qt, kt, vt)
        else:                           # decode: no grad, traced prefix
            out = ops.flash_attention(qt, kt, vt, causal=causal,
                                      window=window, kv_valid=kv_valid)
        return jnp.swapaxes(out, 1, 2)

    def wkv(self, r, k, v, log_w, u, *, chunk: int):
        return _diff_wkv(chunk)(r, k, v, log_w, u)

    def entropy_gate(self, logits, tau):
        V = logits.shape[-1]
        lead = logits.shape[:-1]
        H, ex = ops.entropy_exit(logits.reshape(-1, V), tau)
        return H.reshape(lead), ex.reshape(lead)


# ---------------------------------------------------------------------------
# custom_vjp wrappers for the training sites (Pallas has no autodiff rule)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _diff_flash_attention(causal: bool, window: Optional[int]):
    """Pallas flash forward in kernel layout (B,H,T,D); backward = VJP of
    the jnp oracle (a flash-style recompute: nothing but q/k/v is saved)."""

    def ref_fwd(q, k, v):
        return kref.flash_attention_ref(q, k, v, causal=causal, window=window)

    @jax.custom_vjp
    def fa(q, k, v):
        return ops.flash_attention(q, k, v, causal=causal, window=window)

    def fwd(q, k, v):
        return fa(q, k, v), (q, k, v)

    def bwd(res, g):
        _, vjp = jax.vjp(ref_fwd, *res)
        return vjp(g)

    fa.defvjp(fwd, bwd)
    return fa


@functools.lru_cache(maxsize=None)
def _diff_wkv(chunk: int):
    """Pallas chunked wkv forward (model layout, with the carried state);
    backward = VJP of the token-scan oracle."""

    def ref_fwd(r, k, v, log_w, u):
        return kref.rwkv_wkv_ref_model(r, k, v, log_w, u)

    @jax.custom_vjp
    def wkv(r, k, v, log_w, u):
        return ops.rwkv_wkv(r, k, v, log_w, u, chunk=chunk,
                            return_state=True)

    def fwd(r, k, v, log_w, u):
        return wkv(r, k, v, log_w, u), (r, k, v, log_w, u)

    def bwd(res, g):
        _, vjp = jax.vjp(ref_fwd, *res)
        return vjp(g)

    wkv.defvjp(fwd, bwd)
    return wkv


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


_BACKENDS = {}


def register_backend(backend: KernelBackend) -> KernelBackend:
    """Register a backend instance under ``backend.name``; later
    registrations under the same name win (tests swap in probes)."""
    _BACKENDS[backend.name] = backend
    return backend


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


def get_backend(name: str = "auto") -> KernelBackend:
    return _BACKENDS[resolve_kernels(name)]


def backend_for(cfg) -> KernelBackend:
    """The backend a ``ModelConfig`` selects (``cfg.kernels``, default
    ``"auto"`` for configs predating the knob)."""
    return get_backend(getattr(cfg, "kernels", "auto"))


register_backend(ReferenceBackend())
register_backend(PallasBackend())


# ---------------------------------------------------------------------------
# analytic FLOP counts of the routed sites (roofline reporting)
# ---------------------------------------------------------------------------


def attention_site_flops(cfg, batch: int, seq_len: int,
                         kind: str = "train") -> float:
    """FLOPs of the routed attention score+value matmuls for one forward:
    ``2 * 2 * B * H * Tq * Tk_eff * hd`` per attention layer.  ``kind``
    "decode" means Tq = 1 against a ``seq_len``-deep cache."""
    H, hd = cfg.num_heads, cfg.head_dim
    Tk = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    Tq = 1 if kind == "decode" else seq_len
    per_layer = 4.0 * batch * H * Tq * Tk * hd
    n_attn = sum(b in ("attn", "shared_attn") for b in cfg.block_pattern)
    return per_layer * n_attn


def wkv_site_flops(cfg, batch: int, seq_len: int,
                   kind: str = "train") -> float:
    """FLOPs of the routed chunked-wkv per forward: per token per head,
    ~``4*Q*K`` intra-chunk (scores + values over the Q-token chunk) plus
    ~``4*K*K`` inter-chunk/state work."""
    if cfg.ssm is None or cfg.ssm.kind != "rwkv6":
        return 0.0
    s, K = cfg.ssm, cfg.ssm.head_dim
    H = cfg.d_model // K
    T = 1 if kind == "decode" else seq_len
    Q = min(s.chunk_size, T)
    n_wkv = sum(b == "rwkv6" for b in cfg.block_pattern)
    return batch * T * H * K * (4.0 * Q + 4.0 * K) * n_wkv
