"""phi3-medium-14b [dense] — 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352, RoPE + SwiGLU + GQA.  [arXiv:2404.14219]"""
from __future__ import annotations

from repro.config import HeteroProfile, ModelConfig

EXITS = (10, 20, 30)


def config(sliding_window=None) -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b", arch_type="dense",
        num_layers=40, d_model=5120, num_heads=40, num_kv_heads=10,
        d_ff=17920, vocab_size=100352, head_dim=128,
        rope_theta=10000.0, act="silu", exit_layers=EXITS,
        sliding_window=sliding_window,
        source="arXiv:2404.14219",
    )


def smoke() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name="phi3-medium-14b-smoke", arch_type="dense",
        num_layers=4, d_model=256, num_heads=8, num_kv_heads=2,
        d_ff=512, vocab_size=512, head_dim=32, exit_layers=(1, 2),
        dtype=jnp.float32, param_dtype=jnp.float32,
        source="arXiv:2404.14219",
    )


def profile() -> HeteroProfile:
    # paper setting: 12 clients, 4 per split depth
    return HeteroProfile(split_layers=(EXITS[0],) * 4 + (EXITS[1],) * 4
                         + (EXITS[2],) * 4)
