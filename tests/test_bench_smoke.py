"""Smoke-test the engine benchmark end-to-end at CI size: two tiny rounds
per engine, then validate the emitted ``BENCH_fused.json`` and
``BENCH_spmd.json`` schemas so the benchmark can't silently rot."""
import json
import os

import jax
import pytest

from benchmarks import fused_vs_reference


@pytest.fixture(scope="module")
def bench_artifacts(tmp_path_factory):
    """One tiny benchmark run shared by the schema tests."""
    d = tmp_path_factory.mktemp("bench")
    out = os.path.join(d, "BENCH_fused.json")
    spmd_out = os.path.join(d, "BENCH_spmd.json")
    fsdp_out = os.path.join(d, "BENCH_spmd_fsdp.json")
    rows = fused_vs_reference.run(rounds=2, clients=4, batch_size=32,
                                  out=out, spmd_out=spmd_out,
                                  fsdp_out=fsdp_out)
    return rows, out, spmd_out, fsdp_out


def test_fused_benchmark_emits_valid_json(bench_artifacts):
    rows, out, _, _ = bench_artifacts

    # rows consumable by benchmarks/run.py's CSV emitter; the spmd and
    # spmd_fsdp rows are present exactly when those legs ran on this host
    assert len(rows) in (2, 3, 4)
    if len(jax.devices()) == 1:
        assert len(rows) == 2               # spmd legs need a mesh
    for r in rows:
        assert set(("name", "us_per_call", "derived")) <= set(r)

    with open(out) as f:
        data = json.load(f)
    assert set(fused_vs_reference.SCHEMA_KEYS) <= set(data)
    assert data["benchmark"] == "fused_vs_reference"
    assert data["config"]["clients"] == 4
    assert len(data["config"]["splits"]) == 4
    for eng in ("reference", "fused"):
        assert data[eng]["wall_s"] > 0
        assert data[eng]["rounds_per_sec"] > 0
    assert data["speedup"] == pytest.approx(
        data["reference"]["wall_s"] / data["fused"]["wall_s"])
    # engines trained on identical minibatches: metrics must agree
    assert data["max_metric_delta"] < 1e-4


def test_spmd_benchmark_manifest_records_execution_path(bench_artifacts):
    """The three-way manifest must always say what actually ran: real
    timings (with the engine_path note) on a multi-device host, or an
    explicit skip reason on a single-device one — never a silent absence."""
    _, _, spmd_out, _ = bench_artifacts
    with open(spmd_out) as f:
        data = json.load(f)
    assert set(fused_vs_reference.SPMD_SCHEMA_KEYS) <= set(data)
    assert data["benchmark"] == "spmd_vs_fused_vs_reference"
    assert data["config"]["devices"] == len(jax.devices())
    assert data["speedup"]["fused"] > 0
    # the leg is real-or-skip-reason, keyed on what actually ran (a
    # multi-device host can still skip, e.g. batch not dividing the mesh)
    if "skipped" in data["spmd"]:
        assert data["spmd"]["skipped"]          # non-empty reason
        assert data["speedup"]["spmd"] is None
        if len(jax.devices()) == 1:
            assert "device" in data["spmd"]["skipped"]
    else:
        assert data["spmd"]["wall_s"] > 0
        assert data["max_metric_delta"]["spmd"] < 1e-4
        assert data["spmd"]["engine_path"] == "spmd"
    if len(jax.devices()) == 1:
        assert "skipped" in data["spmd"]


def test_overlap_leg_reports_fraction_and_zero_delta(bench_artifacts):
    """The staging-pipeline leg always runs (on the spmd engine when a
    mesh exists, else fused): both on and off walls are real, the on/off
    trajectories are identical (the pipeline only reorders host work),
    and the stats expose a bounded overlap fraction."""
    _, _, spmd_out, _ = bench_artifacts
    with open(spmd_out) as f:
        data = json.load(f)
    ov = data["overlap"]
    expected = "fused" if "skipped" in data["spmd"] else "spmd"
    assert ov["engine"] == expected
    for leg in ("on", "off"):
        assert ov[leg]["wall_s"] > 0
        assert ov[leg]["chunks"] >= 1
        assert 0.0 <= ov[leg]["overlap_fraction"] <= 1.0
    assert ov["off"]["overlap_fraction"] == 0.0     # serial staging hides 0
    assert ov["on"]["overlap"] and not ov["off"]["overlap"]
    assert ov["on_off_metric_delta"] == 0.0
    assert ov["max_metric_delta_vs_reference"] < 1e-4
    if "stage_stats" in data.get("spmd", {}):
        assert data["spmd"]["stage_stats"]["chunks"] >= 1


def test_spmd_fsdp_manifest_real_or_skip_reason(bench_artifacts):
    """The recipe-sharded leg's manifest (BENCH_spmd_fsdp.json) is
    real-or-skip-reason like the spmd one, records the recipe and lanes
    mesh, and — when it ran — stays inside the delta gate's bound."""
    _, _, _, fsdp_out = bench_artifacts
    with open(fsdp_out) as f:
        data = json.load(f)
    assert set(fused_vs_reference.FSDP_SCHEMA_KEYS) <= set(data)
    assert data["benchmark"] == "spmd_fsdp_vs_fused_vs_reference"
    assert data["config"]["recipe"] == "greedy"
    if "skipped" in data["spmd_fsdp"]:
        assert data["spmd_fsdp"]["skipped"]     # non-empty reason
        assert data["speedup"]["spmd_fsdp"] is None
        if len(jax.devices()) < 4:
            assert "device" in data["spmd_fsdp"]["skipped"]
    else:
        assert len(jax.devices()) >= 4
        assert "lanes" in data["config"]["mesh"]
        assert data["spmd_fsdp"]["wall_s"] > 0
        assert data["spmd_fsdp"]["engine_path"] == "spmd"
        assert data["max_metric_delta"]["spmd_fsdp"] < 1e-4
