"""Jitted, batched evaluation over a ``TrainState``.

Replaces the old per-batch ``float()`` host-sync loops in
the pre-facade ``evaluate``/``evaluate_adaptive``: the test set is padded to
whole batches with a validity mask (so the tail batch is *scored*, not
dropped), per-batch sums accumulate inside one ``lax.scan`` per client, and
the host sees a single 5-vector per client.

The entropy threshold ``tau`` enters the compiled function as a traced
scalar, so sweeping thresholds (benchmarks/fig2_threshold.py) reuses one
compilation.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.state import TrainState
from repro.config import HeteroProfile
from repro.core.losses import softmax_entropy

# accumulator layout of one scan over batches
_CLIENT_OK, _SERVER_OK, _ADAPTIVE_OK, _EXITS, _ENT_SUM = range(5)


def pad_batches(x: np.ndarray, y: np.ndarray, batch_size: int
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Reshape a test set into ``[nb, B, ...]`` whole batches plus a 0/1
    validity mask, padding the tail batch by repeating the last sample.
    Returns ``(xb, yb, mask, n)`` with ``mask.sum() == n == len(x)``."""
    n = len(x)
    if n == 0:
        raise ValueError("cannot evaluate an empty dataset")
    bs = min(batch_size, n)
    nb = -(-n // bs)                              # ceil division
    pad = nb * bs - n
    if pad:
        x = np.concatenate([x, np.repeat(x[-1:], pad, axis=0)])
        y = np.concatenate([y, np.repeat(y[-1:], pad, axis=0)])
    mask = np.zeros((nb * bs,), np.float32)
    mask[:n] = 1.0
    return (x.reshape(nb, bs, *x.shape[1:]), y.reshape(nb, bs),
            mask.reshape(nb, bs), n)


class SplitEvaluator:
    """Per-client evaluation of client-side, server-side, and entropy-gated
    adaptive (Alg. 3) predictions, one compiled scan per split layer."""

    def __init__(self, model, profile: HeteroProfile, strategy: str):
        self.model = model
        self.profile = profile
        self.strategy = strategy
        self._fns: Dict[int, Callable] = {}

    def _fn(self, li: int) -> Callable:
        if li in self._fns:
            return self._fns[li]
        model = self.model

        def sums(client, server, xb, yb, mask, tau):
            def body(acc, inp):
                x, y, m = inp
                h, clog, _ = model.client_forward(client["trainable"],
                                                  client["state"], x,
                                                  train=False)
                slog, _ = model.server_forward(server["trainable"],
                                               server["state"], h, li,
                                               train=False)
                cpred = jnp.argmax(clog, axis=-1)
                spred = jnp.argmax(slog, axis=-1)
                H = softmax_entropy(clog)
                exit_mask = (H < tau).astype(jnp.float32)  # Alg. 3: H < tau
                apred = jnp.where(exit_mask > 0, cpred, spred)
                batch = jnp.stack([
                    jnp.sum((cpred == y) * m),
                    jnp.sum((spred == y) * m),
                    jnp.sum((apred == y) * m),
                    jnp.sum(exit_mask * m),
                    jnp.sum(H * m),
                ])
                return acc + batch, None

            acc, _ = jax.lax.scan(body, jnp.zeros((5,), jnp.float32),
                                  (xb, yb, mask))
            return acc

        self._fns[li] = jax.jit(sums)
        return self._fns[li]

    def _per_client_sums(self, state: TrainState, x, y, tau: float,
                         batch_size: int):
        xb, yb, mask, n = pad_batches(np.asarray(x), np.asarray(y),
                                      batch_size)
        xb, yb, mask = jnp.asarray(xb), jnp.asarray(yb), jnp.asarray(mask)
        out = []
        for i, li in enumerate(self.profile.split_layers):
            sidx = 0 if self.strategy == "sequential" else i
            acc = self._fn(li)(state.clients[i], state.servers[sidx],
                               xb, yb, mask, jnp.float32(tau))
            out.append(np.asarray(acc))          # one host sync per client
        return out, n

    def evaluate(self, state: TrainState, x, y, batch_size: int = 512
                 ) -> Dict[str, Any]:
        """Per-client client-side and server-side accuracy over the FULL
        test set (tail batch included)."""
        sums, n = self._per_client_sums(state, x, y, 0.0, batch_size)
        return {"client_acc": [float(s[_CLIENT_OK]) / n for s in sums],
                "server_acc": [float(s[_SERVER_OK]) / n for s in sums],
                "split_layers": list(self.profile.split_layers)}

    def evaluate_adaptive(self, state: TrainState, x, y, tau: float,
                          batch_size: int = 512) -> Dict[str, Any]:
        """Alg. 3 collaborative inference at entropy threshold ``tau``
        (exit iff H < tau; see docs/DESIGN.md on the paper's sign convention)."""
        sums, n = self._per_client_sums(state, x, y, tau, batch_size)
        return {"acc": [float(s[_ADAPTIVE_OK]) / n for s in sums],
                "client_ratio": [float(s[_EXITS]) / n for s in sums],
                "mean_entropy": [float(s[_ENT_SUM]) / n for s in sums]}
