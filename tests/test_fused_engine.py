"""Equivalence contract between the fused (scan+vmap) engine and the
paper-faithful reference engine, plus adaptive-inference threshold edges
shared by both engines.  See docs/ENGINES.md."""
import jax
import numpy as np
import pytest

from repro.api import TrainSession
from repro.config import HeteroProfile, OptimizerConfig, SplitEEConfig
from repro.core.splitee import MLPSplitModel, stack_pytrees, unstack_pytrees

TOL = 1e-5


def _blob_data(n, d, classes, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(classes, d)) * 2.0
    y = rng.integers(0, classes, n).astype(np.int32)
    x = (centers[y] + rng.normal(size=(n, d))).astype(np.float32)
    return x, y


def _make(engine, strategy, splits=(1, 2, 2, 3), aggregate_every=1,
          grad_mode="eq1"):
    x, y = _blob_data(600, 16, 3)
    n = len(splits)
    model = MLPSplitModel(in_dim=16, hidden=32, num_classes=3, num_layers=4,
                          seed=0)
    parts = [(x[i::n], y[i::n]) for i in range(n)]
    tr = TrainSession.from_config(
        model,
        SplitEEConfig(profile=HeteroProfile(tuple(splits)),
                      strategy=strategy, aggregate_every=aggregate_every),
        OptimizerConfig(lr=3e-3, total_steps=50),
        parts, batch_size=64, engine=engine, grad_mode=grad_mode)
    return tr, (x, y)


def _assert_trees_close(a, b, msg=""):
    jax.tree.map(lambda u, v: np.testing.assert_allclose(
        np.asarray(u), np.asarray(v), atol=TOL, err_msg=msg), a, b)


def _assert_engines_match(ref, fus):
    assert len(ref.history) == len(fus.history)
    for a, b in zip(ref.history, fus.history):
        assert a.round == b.round
        assert abs(a.client_loss - b.client_loss) < TOL
        assert abs(a.server_loss - b.server_loss) < TOL
    for i in range(ref.ctx.N):
        _assert_trees_close(ref.state.clients[i]["trainable"],
                            fus.state.clients[i]["trainable"], f"client {i}")
        _assert_trees_close(ref.state.servers[i]["trainable"],
                            fus.state.servers[i]["trainable"], f"server {i}")
        _assert_trees_close((ref.state.client_opts[i].m,
                             ref.state.client_opts[i].v),
                            (fus.state.client_opts[i].m,
                             fus.state.client_opts[i].v),
                            f"client opt {i}")


# ---------------------------------------------------------------------------
# numerical equivalence to the reference engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ["averaging", "distributed"])
def test_fused_matches_reference(strategy):
    """≥3 rounds with E=2 local epochs: params, opt state and per-round
    metrics agree with the per-client reference to ~1e-5."""
    ref, _ = _make("reference", strategy)
    fus, _ = _make("fused", strategy)
    ref.train(4, local_epochs=2)
    fus.train(4, local_epochs=2)
    _assert_engines_match(ref, fus)


def test_fused_matches_reference_aggregate_every_2():
    """aggregate_every=2: rounds 0/2 skip Eq. (1), rounds 1/3 apply it — the
    in-graph masked aggregation must hit exactly the reference boundaries."""
    ref, _ = _make("reference", "averaging", aggregate_every=2)
    fus, _ = _make("fused", "averaging", aggregate_every=2)
    ref.train(4)
    fus.train(4)
    _assert_engines_match(ref, fus)
    # boundary really aggregated: deepest common layers identical
    for key in ("layer4", "head"):
        w0 = np.asarray(fus.state.servers[0]["trainable"][key]["w"])
        for s in fus.state.servers[1:]:
            np.testing.assert_allclose(w0, np.asarray(s["trainable"][key]["w"]),
                                       atol=1e-6)


def test_fused_chunked_matches_single_chunk():
    """Chunking the scan (chunk_rounds) must not change the trajectory."""
    one, _ = _make("fused", "averaging", aggregate_every=2)
    many, _ = _make("fused", "averaging", aggregate_every=2)
    one.train(6)
    many.train(6, chunk_rounds=2)
    _assert_engines_match(one, many)


def test_auto_chunk_rounds_respects_stage_budget():
    """chunk_rounds=0 is a *budgeted* default, not stage-everything: when
    the whole run's pre-staged tensors would blow the budget, the engine
    picks the largest chunk that fits (floor 1) — without changing the
    trajectory."""
    one, _ = _make("fused", "averaging", aggregate_every=2)
    auto, _ = _make("fused", "averaging", aggregate_every=2)
    eng = auto.engine
    per_round = eng._round_stage_bytes(local_epochs=1)
    # 4 clients x 64x16 f32 x + 64 i32 y = 4 * (64*16*4 + 64*4)
    assert per_round == 4 * (64 * 16 * 4 + 64 * 4)
    # a budget of ~2.5 rounds -> chunks of 2; floor at 1 when even one
    # round exceeds the budget; whole run when it fits
    eng.stage_budget_bytes = int(2.5 * per_round)
    assert eng._auto_chunk_rounds(6, 1) == 2
    assert eng._auto_chunk_rounds(1, 1) == 1
    eng.stage_budget_bytes = per_round - 1
    assert eng._auto_chunk_rounds(6, 1) == 1
    eng.stage_budget_bytes = 100 * per_round
    assert eng._auto_chunk_rounds(6, 1) == 6
    assert eng._auto_chunk_rounds(6, 2) == 6   # 2x data still fits
    # trained under the tight budget, the trajectory is unchanged
    eng.stage_budget_bytes = int(2.5 * per_round)
    one.train(6)
    auto.train(6)                              # chunk_rounds=0 -> auto
    _assert_engines_match(one, auto)


def test_fused_sum_grad_mode_matches_eq1():
    """The split-boundary stop_gradient decouples the client/server
    parameter families, so the 'sum' mode's single fused backward computes
    the same gradients as the two-pass 'eq1' routing on the split-net
    adapters (the modes differ only in how the backward is staged)."""
    eq1, _ = _make("fused", "averaging")
    summ, _ = _make("fused", "averaging", grad_mode="sum")
    eq1.train(3, local_epochs=2)
    summ.train(3, local_epochs=2)
    _assert_engines_match(eq1, summ)


def test_reference_rejects_sum_grad_mode():
    with pytest.raises(ValueError, match="eq1"):
        _make("reference", "averaging", grad_mode="sum")


def test_fused_rejects_sequential():
    with pytest.raises(ValueError, match="[Ss]equential"):
        _make("fused", "sequential")


def test_fused_rejects_ragged_cohort_batches():
    """Two clients share a cut layer but batch_iterator clamps one shard
    below batch_size — lanes can't stack, so construction must fail loudly
    (the reference engine still handles this profile)."""
    x, y = _blob_data(200, 16, 3)
    model = MLPSplitModel(in_dim=16, hidden=32, num_classes=3, num_layers=4)
    parts = [(x[:100], y[:100]), (x[100:140], y[100:140])]   # 100 vs 40
    cfg = SplitEEConfig(profile=HeteroProfile((2, 2)), strategy="averaging")
    with pytest.raises(ValueError, match="batch"):
        TrainSession.from_config(model, cfg, OptimizerConfig(), parts,
                                 batch_size=64, engine="fused")
    TrainSession.from_config(model, cfg, OptimizerConfig(), parts,
                             batch_size=64,
                             engine="reference").train(1)    # oracle is fine


def test_stack_unstack_roundtrip():
    model = MLPSplitModel(in_dim=8, hidden=16, num_classes=3, num_layers=4)
    clients = [model.make_client(2) for _ in range(3)]
    stacked = model.stack_clients(clients)
    w = stacked["trainable"]["layers"]["layer1"]["w"]
    assert w.shape[0] == 3
    back = model.unstack(stacked, 3)
    for a, b in zip(clients, back):
        _assert_trees_close(a, b)
    # module-level helpers agree with the adapter methods
    _assert_trees_close(stack_pytrees(clients), stacked)
    for a, b in zip(unstack_pytrees(stacked, 3), back):
        _assert_trees_close(a, b)


# ---------------------------------------------------------------------------
# evaluate_adaptive threshold edges (both engines share the implementation)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["reference", "fused"])
def test_adaptive_tau_zero_is_pure_server(engine):
    """tau=0: entropy H >= 0 is never < 0, so nothing exits at the client —
    accuracy must equal the server-side path."""
    tr, (x, y) = _make(engine, "averaging")
    tr.train(3)
    ad = tr.evaluate_adaptive(x[:300], y[:300], tau=0.0, batch_size=100)
    assert ad["client_ratio"] == [0.0] * tr.ctx.N
    ev = tr.evaluate(x[:300], y[:300], batch_size=100)
    np.testing.assert_allclose(ad["acc"], ev["server_acc"], atol=1e-6)


@pytest.mark.parametrize("engine", ["reference", "fused"])
def test_adaptive_tau_above_max_entropy_is_pure_client(engine):
    """tau > log(num_classes) >= max H: every sample exits at the client."""
    tr, (x, y) = _make(engine, "averaging")
    tr.train(3)
    tau = float(np.log(3)) + 0.1
    ad = tr.evaluate_adaptive(x[:300], y[:300], tau=tau, batch_size=100)
    assert ad["client_ratio"] == [1.0] * tr.ctx.N
    ev = tr.evaluate(x[:300], y[:300], batch_size=100)
    np.testing.assert_allclose(ad["acc"], ev["client_acc"], atol=1e-6)
