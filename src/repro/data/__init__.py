from repro.data.synthetic import SyntheticImageDataset, SyntheticLMDataset  # noqa: F401
from repro.data.pipeline import ClientPartitioner, batch_iterator  # noqa: F401
