"""Stub modality frontends (the one allowed carve-out, see docs/DESIGN.md §4).

``input_specs`` for audio/VLM architectures hands the backbone *precomputed*
frame/patch embeddings of the right shape; this module contributes only the
linear projector that maps frontend feature dims into ``d_model``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.common import fan_in_init

# feature dims of the (stubbed) frontends
WHISPER_FRAME_DIM = 768          # whisper-small encoder state dim
SIGLIP_PATCH_DIM = 1152          # SigLIP-So400m patch embedding dim
NUM_VISION_PATCHES = 256         # paligemma 224px / 14px patches
WHISPER_SOURCE_LEN = 1500        # 30 s of audio after conv striding


def init_projector(rng, in_dim: int, cfg: ModelConfig) -> dict:
    return {"w": fan_in_init(rng, (in_dim, cfg.d_model), cfg.param_dtype)}


def project(params: dict, feats: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("bsf,fd->bsd", feats, params["w"])
