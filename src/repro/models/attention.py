"""Attention mixers: GQA (with RoPE + optional sliding window), DeepSeek MLA,
and encoder-decoder cross attention.  The score/value contraction of GQA and
cross attention routes through ``repro.kernels.dispatch`` — the
``cfg.kernels`` knob picks the Pallas flash kernel or the pure-jnp ``_sdpa``
below, which doubles as the equivalence oracle.  MLA stays on the inline
reference path (its weight-absorbed latent decode has no kernel yet).

Cache contract (decode):
  GQA  : {"k": (B, W, Hkv, hd), "v": (B, W, Hkv, hd)}  — W = window or max_len.
         Keys are stored *already roped* (absolute positions), so a ring
         buffer needs no re-rotation.
  MLA  : {"ckv": (B, W, kv_lora), "k_rope": (B, W, rope_dim)}
``cache_len`` is the number of tokens already written (int32 scalar).
"""
from __future__ import annotations

import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import MLAConfig, ModelConfig
from repro.kernels import dispatch
from repro.models.common import fan_in_init, init_rmsnorm, rmsnorm, zeros
from repro.models.rope import apply_rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------


def causal_mask(q_len: int, kv_len: int, window: Optional[int] = None,
                q_offset: int = 0) -> jnp.ndarray:
    """(q_len, kv_len) boolean mask; True = attend.  ``q_offset`` shifts query
    positions (for chunked prefill).  ``window`` bounds the lookback."""
    qpos = jnp.arange(q_len)[:, None] + q_offset
    kpos = jnp.arange(kv_len)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m


def _sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
          mask: Optional[jnp.ndarray], scale: float) -> jnp.ndarray:
    """q: (B,T,H,hd)  k/v: (B,S,Hkv,hd) with H % Hkv == 0 (GQA broadcast)."""
    B, T, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, T, Hkv, g, hd)
    logits = jnp.einsum("bthgd,bshd->bhgts", qg, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgts,bshd->bthgd", probs, v)
    return out.reshape(B, T, H, hd)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def init_gqa(rng, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    H, Hkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(rng, 4)
    p = {
        "wq": fan_in_init(ks[0], (d, H, hd), cfg.param_dtype, fan_in=d),
        "wk": fan_in_init(ks[1], (d, Hkv, hd), cfg.param_dtype, fan_in=d),
        "wv": fan_in_init(ks[2], (d, Hkv, hd), cfg.param_dtype, fan_in=d),
        "wo": fan_in_init(ks[3], (H, hd, d), cfg.param_dtype, fan_in=H * hd),
    }
    if cfg.use_qkv_bias:
        p["bq"] = zeros((H, hd), cfg.param_dtype)
        p["bk"] = zeros((Hkv, hd), cfg.param_dtype)
        p["bv"] = zeros((Hkv, hd), cfg.param_dtype)
    return p


def init_gqa_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    W = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    return {
        "k": jnp.zeros((batch, W, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, W, cfg.num_kv_heads, cfg.head_dim), dtype),
    }


def gqa_forward(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
                cfg: ModelConfig, *, cache: Optional[dict] = None,
                cache_len: Optional[jnp.ndarray] = None,
                ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Full causal (train/prefill) when ``cache is None``; single-token decode
    against a (ring-buffer) cache otherwise.  The attention contraction runs
    on the ``cfg.kernels`` backend."""
    backend = dispatch.backend_for(cfg)
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"])
    if cfg.use_qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = backend.attention(q, k, v, causal=True,
                                window=cfg.sliding_window)
    else:
        # write (k, v) into the (ring) buffer, attend over it.  Modes:
        # prefill (T > 1, cache_len == 0) and decode (T == 1, ring).  Token
        # position p always lives at slot p % W so decode needs no re-layout.
        T = x.shape[1]
        W = cache["k"].shape[1]
        if T > 1 and T >= W:
            # prefill longer than the window: full in-flight SWA attention,
            # then keep only the last W tokens, rolled to slot p % W.
            out = backend.attention(q, k, v, causal=True,
                                    window=cfg.sliding_window)
            shift = (T - W) % W
            ck = jnp.roll(k[:, T - W:], shift, axis=1)
            cv = jnp.roll(v[:, T - W:], shift, axis=1)
            cache = {"k": ck, "v": cv}
        else:
            slot = (cache_len % W).astype(jnp.int32)
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
            cache = {"k": ck, "v": cv}
            if T > 1:
                # short prefill: causal over the freshly written [0, T)
                # slots (ragged Tq < Tk — the diagonal masks slots >= T)
                out = backend.attention(q, ck, cv, causal=True,
                                        window=cfg.sliding_window)
            else:
                # decode: traced valid ring prefix, never recompiles
                n_valid = jnp.minimum(cache_len + 1, W)
                out = backend.attention(q, ck, cv, kv_valid=n_valid)
    out = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return out.astype(x.dtype), cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------


def init_mla(rng, cfg: ModelConfig) -> dict:
    m: MLAConfig = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    ks = jax.random.split(rng, 7)
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": fan_in_init(ks[0], (d, m.q_lora_rank), cfg.param_dtype),
        "q_norm": init_rmsnorm(m.q_lora_rank, cfg.param_dtype),
        "w_uq": fan_in_init(ks[1], (m.q_lora_rank, H, qk_dim), cfg.param_dtype,
                            fan_in=m.q_lora_rank),
        "w_dkv": fan_in_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                             cfg.param_dtype),
        "kv_norm": init_rmsnorm(m.kv_lora_rank, cfg.param_dtype),
        "w_uk": fan_in_init(ks[3], (m.kv_lora_rank, H, m.qk_nope_head_dim),
                            cfg.param_dtype, fan_in=m.kv_lora_rank),
        "w_uv": fan_in_init(ks[4], (m.kv_lora_rank, H, m.v_head_dim),
                            cfg.param_dtype, fan_in=m.kv_lora_rank),
        "wo": fan_in_init(ks[5], (H, m.v_head_dim, d), cfg.param_dtype,
                          fan_in=H * m.v_head_dim),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    m: MLAConfig = cfg.mla
    W = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    return {
        "ckv": jnp.zeros((batch, W, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, W, m.qk_rope_head_dim), dtype),
    }


def _mla_project_q(params, x, positions, m: MLAConfig, cfg):
    cq = rmsnorm(params["q_norm"], jnp.einsum("btd,dr->btr", x, params["w_dq"]),
                 cfg.norm_eps)
    q = jnp.einsum("btr,rhk->bthk", cq, params["w_uq"])
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_project_kv(params, x, positions, m: MLAConfig, cfg):
    dkv = jnp.einsum("btd,dr->btr", x, params["w_dkv"])
    ckv = rmsnorm(params["kv_norm"], dkv[..., : m.kv_lora_rank], cfg.norm_eps)
    k_rope = dkv[..., m.kv_lora_rank:][..., None, :]          # (B,T,1,rope)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[..., 0, :]
    return ckv, k_rope


def mla_forward(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
                cfg: ModelConfig, *, cache: Optional[dict] = None,
                cache_len: Optional[jnp.ndarray] = None,
                ) -> Tuple[jnp.ndarray, Optional[dict]]:
    m: MLAConfig = cfg.mla
    H = cfg.num_heads
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = _mla_project_q(params, x, positions, m, cfg)

    if cache is None:
        # train/prefill: naive expansion (matmul-dense, MXU-friendly).
        ckv, k_rope = _mla_project_kv(params, x, positions, m, cfg)
        k_nope = jnp.einsum("bsr,rhk->bshk", ckv, params["w_uk"])
        v = jnp.einsum("bsr,rhk->bshk", ckv, params["w_uv"])
        T = x.shape[1]
        mask = causal_mask(T, T, cfg.sliding_window)
        logits = (jnp.einsum("bthk,bshk->bhts", q_nope, k_nope)
                  + jnp.einsum("bthk,bsk->bhts", q_rope, k_rope)
                  ).astype(jnp.float32) * scale
        logits = jnp.where(mask, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhts,bshk->bthk", probs, v)
        new_cache = None
    else:
        # decode: weight-absorbed attention in latent space (T == 1).
        ckv_t, k_rope_t = _mla_project_kv(params, x, positions, m, cfg)
        W = cache["ckv"].shape[1]
        slot = (cache_len % W).astype(jnp.int32)
        ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv_t, (0, slot, 0))
        k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_t, (0, slot, 0))
        new_cache = {"ckv": ckv, "k_rope": k_rope}
        n_valid = jnp.minimum(cache_len + 1, W)
        mask = (jnp.arange(W) < n_valid)[None, None, None, :]  # (1,1,1,W)
        q_abs = jnp.einsum("bthk,rhk->bthr", q_nope, params["w_uk"])
        logits = (jnp.einsum("bthr,bsr->bhts", q_abs, ckv)
                  + jnp.einsum("bthk,bsk->bhts", q_rope, k_rope)
                  ).astype(jnp.float32) * scale
        logits = jnp.where(mask, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        o_lat = jnp.einsum("bhts,bsr->bthr", probs, ckv)
        out = jnp.einsum("bthr,rhk->bthk", o_lat, params["w_uv"])
    out = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return out.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------


def init_cross_attn(rng, cfg: ModelConfig) -> dict:
    d, hd, H = cfg.d_model, cfg.head_dim, cfg.num_heads
    ks = jax.random.split(rng, 4)
    return {
        "wq": fan_in_init(ks[0], (d, H, hd), cfg.param_dtype, fan_in=d),
        "wk": fan_in_init(ks[1], (d, H, hd), cfg.param_dtype, fan_in=d),
        "wv": fan_in_init(ks[2], (d, H, hd), cfg.param_dtype, fan_in=d),
        "wo": fan_in_init(ks[3], (H, hd, d), cfg.param_dtype, fan_in=H * hd),
    }


def cross_attn_forward(params: dict, x: jnp.ndarray, enc: jnp.ndarray,
                       cfg: ModelConfig) -> jnp.ndarray:
    """x: (B,T,d) decoder stream; enc: (B,S,d) encoder states (stub frontend)."""
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", enc, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc, params["wv"])
    out = dispatch.backend_for(cfg).attention(q, k, v)
    return jnp.einsum("bthk,hkd->btd", out, params["wo"]).astype(x.dtype)
