"""Fused streaming softmax-entropy + exit gate (Pallas, TPU target).

Alg. 3 phases 1-2 over the exit-head logits: H = -sum p log p and the
decision H < tau, WITHOUT materializing the (B, V) softmax in HBM.  For the
256k-vocab assigned archs this matters: logits row = 256000 x 4B = 1 MB; the
fused kernel streams vocab blocks through VMEM keeping three running scalars
per row:
    m = running max,  S = sum e^{x-m},  U = sum e^{x-m} * x
    H = m + log S - U/S        (since H = log Z - E[x])
Rescaling on a new max multiplies S and U by e^{m_old - m_new}.
Grid = (row blocks, vocab blocks); vocab axis sequential with VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _entropy_kernel(tau_ref, x_ref, h_ref, exit_ref, m_scr, s_scr, u_scr, *,
                    vocab: int, block_v: int):
    iv = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(iv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        s_scr[...] = jnp.zeros_like(s_scr)
        u_scr[...] = jnp.zeros_like(u_scr)

    x = x_ref[...].astype(jnp.float32)                       # (Br, Bv)
    col = iv * block_v + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    x = jnp.where(col < vocab, x, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(x, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(x - m_new[:, None])
    # padded lanes have p == exp(NEG_INF - m) == 0, so they contribute nothing
    s_scr[...] = s_scr[...] * alpha + jnp.sum(p, axis=1)
    u_scr[...] = u_scr[...] * alpha + jnp.sum(p * x, axis=1)
    m_scr[...] = m_new

    @pl.when(iv == nv - 1)
    def _finalize():
        S = jnp.maximum(s_scr[...], 1e-30)
        H = m_scr[...] + jnp.log(S) - u_scr[...] / S
        h_ref[...] = H
        exit_ref[...] = (H < tau_ref[0, 0]).astype(jnp.int32)


def entropy_exit_pallas(logits: jnp.ndarray, tau: jnp.ndarray, *,
                        block_rows: int = 8, block_v: int = 2048,
                        interpret: bool = False):
    """logits: (B, V) -> (entropy (B,) f32, exit (B,) int32 0/1).
    B must be a multiple of block_rows (ops.py pads).  ``tau`` is a traced
    (1, 1) float32 scalar living in SMEM — threshold sweeps (the paper's
    Fig. 2 axis) reuse one compilation."""
    B, V = logits.shape
    assert B % block_rows == 0
    tau = jnp.asarray(tau, jnp.float32).reshape(1, 1)
    nv = (V + block_v - 1) // block_v
    grid = (B // block_rows, nv)
    kernel = functools.partial(_entropy_kernel, vocab=V, block_v=block_v)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec((block_rows, block_v), lambda r, iv: (r, iv))],
        out_specs=[
            pl.BlockSpec((block_rows,), lambda r, iv: (r,)),
            pl.BlockSpec((block_rows,), lambda r, iv: (r,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_rows,), jnp.float32),
            pltpu.VMEM((block_rows,), jnp.float32),
            pltpu.VMEM((block_rows,), jnp.float32),
        ],
        interpret=interpret,
    )(tau, logits)
