"""Registry-wide conformance of ``src/repro/configs/``: every architecture
module must expose the ``config()`` / ``smoke()`` / ``profile()`` triple the
``--arch`` CLI resolves through, with a ``HeteroProfile`` whose split layers
are legal cut points of the config it describes — and every smoke config's
cohort carry must produce *legal* ``train_state_specs`` on the 4-device
lanes/data/model host mesh under the named sharding recipes (every sharded
dim divisible; Adam moment specs identical to their params')."""
import dataclasses
import importlib
import pkgutil

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as configs_pkg
from repro import configs as configs_mod
from repro.api.spmd_engine import abstract_cohort_carry
from repro.config import HeteroProfile, ModelConfig, OptimizerConfig
from repro.core.backbone_splitee import BackboneSplitModel
from repro.launch.mesh import MeshSpec, axis_sizes
from repro.launch.shardings import (NAMED_RECIPES, train_state_specs)

ALL_MODULES = sorted(
    m.name for m in pkgutil.iter_modules(configs_pkg.__path__)
    if not m.name.startswith("_"))

#: the 4-device host mesh the mesh CI job runs on (device-free description:
#: spec legality is a pure shape computation)
HOST_MESH = MeshSpec((2, 2, 1), ("lanes", "data", "model"))


def test_registry_covers_all_arch_modules():
    # every assigned arch id resolves to a module in the package
    for arch in configs_mod.all_arch_ids():
        mod = configs_mod.get(arch)
        assert mod.__name__.rsplit(".", 1)[-1] in ALL_MODULES
    # and the package holds exactly the assigned archs + the paper's ResNet
    assert set(ALL_MODULES) == set(configs_mod.ARCH_IDS) | {"resnet18_cifar"}


@pytest.mark.parametrize("name", ALL_MODULES)
def test_module_exposes_triple(name):
    mod = importlib.import_module(f"repro.configs.{name}")
    for fn in ("config", "smoke", "profile"):
        assert callable(getattr(mod, fn, None)), f"{name} lacks {fn}()"


@pytest.mark.parametrize("name", ALL_MODULES)
def test_profile_split_layers_are_legal_cuts(name):
    mod = importlib.import_module(f"repro.configs.{name}")
    cfg = mod.config()
    prof = mod.profile()
    assert isinstance(prof, HeteroProfile)
    assert prof.num_groups >= 1
    for li in prof.split_layers:
        assert 1 <= li < cfg.num_layers, (name, li)
    if isinstance(cfg, ModelConfig):
        # token backbones cut at exit-head boundaries (BackboneSplitModel)
        assert set(prof.split_layers) <= set(cfg.exit_layers), name


@pytest.mark.parametrize("name", ALL_MODULES)
def test_smoke_is_reduced_and_splittable(name):
    mod = importlib.import_module(f"repro.configs.{name}")
    cfg = mod.smoke()
    if not isinstance(cfg, ModelConfig):       # the ResNet paper model
        return
    assert cfg.num_layers <= 4
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    # exit heads exist so the smoke config trains through the adapter
    assert cfg.exit_layers, name
    for li in cfg.exit_layers:
        assert 1 <= li < cfg.num_layers, (name, li)


# ---------------------------------------------------------------------------
# recipe conformance: legal train_state_specs on the 4-device host mesh
# ---------------------------------------------------------------------------


def _p_leaves(tree):
    return jax.tree.flatten(tree, is_leaf=lambda s: isinstance(s, P))[0]


def _smoke_carry(cfg):
    """The 4-client cohort carry of a smoke config, fully abstract (the
    adapter and its parameters build under ``jax.eval_shape`` — nothing
    materializes).  Returns ``(carry, splits)``."""
    cuts = tuple(sorted(cfg.exit_layers))
    splits = tuple(cuts[i % len(cuts)] for i in range(4))
    carry = abstract_cohort_carry(lambda: BackboneSplitModel(cfg, seed=0),
                                  splits, OptimizerConfig(total_steps=8))
    return carry, splits


@pytest.mark.parametrize("recipe_name", ["greedy", "megatron"])
@pytest.mark.parametrize("name", ALL_MODULES)
def test_smoke_train_state_specs_legal_on_host_mesh(name, recipe_name):
    """Every registered arch's smoke cohort carry gets specs from the named
    recipes (with the tiny-leaf floor lowered so sharding actually
    triggers) in which every sharded dim divides its mesh axes and Adam
    moments shard exactly like their params."""
    mod = importlib.import_module(f"repro.configs.{name}")
    cfg = mod.smoke()
    if not isinstance(cfg, ModelConfig):       # the ResNet paper model
        pytest.skip("not a token backbone")
    carry, splits = _smoke_carry(cfg)
    recipe = dataclasses.replace(NAMED_RECIPES[recipe_name],
                                 min_shard_elems=2)
    n_exp = cfg.moe.num_experts if cfg.moe else -1
    specs = train_state_specs(recipe, HOST_MESH, carry, num_experts=n_exp)
    sizes = axis_sizes(HOST_MESH)

    spec_leaves = _p_leaves(specs)
    carry_leaves = jax.tree.leaves(carry)
    assert len(spec_leaves) == len(carry_leaves)
    used = set()
    for leaf, spec in zip(carry_leaves, spec_leaves):
        assert len(spec) <= leaf.ndim, (leaf.shape, spec)
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            used |= set(axes)
            k = int(np.prod([sizes[a] for a in axes]))
            assert dim % k == 0, (name, leaf.shape, spec)
    # the lanes axis is in play exactly when some cohort's lane count
    # divides the 2-way axis (true for every current arch's smoke cuts)
    counts = [splits.count(li) for li in set(splits)]
    if any(c % sizes["lanes"] == 0 for c in counts):
        assert "lanes" in used, name

    # moments mirror their params, cohort by cohort
    for li, (client, copt, server, sopt) in specs.items():
        assert _p_leaves(copt.m) == _p_leaves(client["trainable"]), (name, li)
        assert _p_leaves(copt.v) == _p_leaves(client["trainable"]), (name, li)
        assert _p_leaves(sopt.m) == _p_leaves(server["trainable"]), (name, li)
        assert _p_leaves(sopt.v) == _p_leaves(server["trainable"]), (name, li)
