"""Multi-host launch wiring: ``jax.distributed`` initialization + the
latency-hiding XLA flags, resolved from CLI flags or environment.

The spmd engine itself is topology-agnostic — it shards over whatever
``jax.devices()`` reports.  Going past one process is purely a launch
concern, handled here in two pre-``import jax`` steps (mirroring
``launch.hostdevices``):

  1. :func:`setup_from_argv` scans ``sys.argv`` for
     ``--distributed --coordinator HOST:PORT --num-processes N
     --process-id I`` (env fallbacks ``REPRO_DISTRIBUTED``,
     ``REPRO_COORDINATOR``, ``REPRO_NUM_PROCESSES``,
     ``REPRO_PROCESS_ID``) and, when a distributed run is requested,
     appends the async-collective / latency-hiding scheduler XLA flags
     to ``XLA_FLAGS`` so the Eq. (1) lane-reduce and the recipes' FSDP
     all-gathers overlap compute instead of serializing it.
  2. :func:`maybe_initialize` (first thing in ``main()``, before any
     jax computation) configures the gloo CPU collectives backend and
     calls ``jax.distributed.initialize`` — after which
     ``jax.devices()`` is the *global* device list and every
     ``launch.mesh`` helper spans all processes.

Unset coordinator/count/id fields are left to jax's own cluster
auto-detection (SLURM, GKE, ...); on a bare multi-host launch all three
must be given.  See tests/test_distributed.py for the 2-process CPU
parity harness and docs/ENGINES.md for the launch recipe.
"""
from __future__ import annotations

import os
import sys
from dataclasses import dataclass
from typing import Optional, Sequence

#: XLA flags applied to every distributed launch: schedule collectives
#: concurrently with compute (latency-hiding scheduler + a dedicated
#: high-priority async stream) and pipeline the collectives the spmd
#: engine's sharded step emits (grad all-reduce over the batch axes,
#: FSDP all-gather / reduce-scatter around sharded params).  GPU-prefixed
#: but parse everywhere; XLA:CPU ignores the scheduler hints.
ASYNC_COLLECTIVE_XLA_FLAGS: Sequence[str] = (
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
    "--xla_gpu_enable_pipelined_all_reduce=true",
    "--xla_gpu_enable_pipelined_all_gather=true",
    "--xla_gpu_enable_pipelined_reduce_scatter=true",
)


@dataclass(frozen=True)
class DistributedOptions:
    """A launch's resolved multi-host request (``enabled=False`` for the
    ordinary single-process run)."""

    enabled: bool = False
    coordinator: Optional[str] = None      # "host:port"
    num_processes: Optional[int] = None
    process_id: Optional[int] = None


def _argv_value(flag: str, argv: Sequence[str]) -> Optional[str]:
    for i, a in enumerate(argv):
        if a == flag and i + 1 < len(argv):
            return argv[i + 1]
        if a.startswith(flag + "="):
            return a.split("=", 1)[1]
    return None


def _truthy(v: Optional[str]) -> bool:
    return v is not None and v.strip().lower() not in ("", "0", "false",
                                                       "off", "no")


def _int_option(flag: str, env: str, argv: Sequence[str]) -> Optional[int]:
    """An integer launch option from argv (preferred) or the ``env``
    fallback.  A malformed argv value resolves to ``None`` — argparse
    parses the same flag later and produces the canonical error — but a
    malformed env var raises here: nothing else ever looks at it, and
    silently dropping it would send ``jax.distributed.initialize`` into
    cluster auto-detection, which fails or hangs with no hint of the
    real cause."""
    v = _argv_value(flag, argv)
    if v is not None:
        try:
            return int(v)
        except ValueError:
            return None
    v = os.environ.get(env)
    if v is None or not v.strip():
        return None
    try:
        return int(v)
    except ValueError:
        raise ValueError(
            f"{env}={v!r} is not an integer (fix or unset it; a dropped "
            f"value would fall back to jax cluster auto-detection)"
        ) from None


def resolve_options(argv: Optional[Sequence[str]] = None
                    ) -> DistributedOptions:
    """The launch's :class:`DistributedOptions` from argv flags, with
    ``REPRO_*`` environment fallbacks (so process launchers can export
    instead of templating per-rank command lines)."""
    argv = sys.argv if argv is None else argv
    coord = (_argv_value("--coordinator", argv)
             or os.environ.get("REPRO_COORDINATOR"))
    nproc = _int_option("--num-processes", "REPRO_NUM_PROCESSES", argv)
    pid = _int_option("--process-id", "REPRO_PROCESS_ID", argv)
    enabled = ("--distributed" in argv
               or _truthy(os.environ.get("REPRO_DISTRIBUTED"))
               or coord is not None)
    return DistributedOptions(enabled=enabled, coordinator=coord,
                              num_processes=nproc, process_id=pid)


def setup_from_argv(argv: Optional[Sequence[str]] = None
                    ) -> DistributedOptions:
    """Pre-``import jax`` step: resolve the launch's options and, for a
    distributed run, append :data:`ASYNC_COLLECTIVE_XLA_FLAGS` to
    ``XLA_FLAGS`` (idempotent)."""
    opts = resolve_options(argv)
    if opts.enabled:
        flags = os.environ.get("XLA_FLAGS", "")
        extra = [f for f in ASYNC_COLLECTIVE_XLA_FLAGS
                 if f.split("=", 1)[0] not in flags]
        if extra:
            os.environ["XLA_FLAGS"] = " ".join([flags, *extra]).strip()
    return opts


def maybe_initialize(opts: DistributedOptions) -> None:
    """Bring the process into the ``jax.distributed`` cluster (no-op when
    the launch is not distributed).  Must run before any jax computation:
    the collectives backend and the global device list are locked at
    first backend initialization."""
    if not opts.enabled:
        return
    import jax

    # CPU collectives need an explicit cross-process implementation; gloo
    # ships with jaxlib.  TPU/GPU backends ignore this setting.
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(coordinator_address=opts.coordinator,
                               num_processes=opts.num_processes,
                               process_id=opts.process_id)


def is_coordinator() -> bool:
    """True on the process that owns shared-filesystem side effects
    (checkpoints, driver sidecars): process 0, or any process of a
    non-distributed run."""
    import jax

    return jax.process_index() == 0
