"""``TrainSession`` — the one front door for Hetero-SplitEE training.

A session binds a :class:`~repro.api.protocol.SplitModel` adapter, the
paper's configuration dataclasses, per-client data shards, and a registered
engine; all mutable progress lives in one immutable
:class:`~repro.api.state.TrainState` pytree that the engine consumes and
returns.  Because the state is a plain pytree, a session can be saved,
restored, and handed between engines with a resume-equivalence guarantee:
training 2k rounds equals training k, saving, restoring, and training k —
on parameters, Adam moments, BN statistics, and per-round metrics
(tests/test_session.py).

    session = TrainSession.from_config(model, splitee_cfg, opt_cfg,
                                       client_data, batch_size=64,
                                       engine="auto")
    session.train(rounds=100, save_every=20, save_dir="ckpt/run1")
    ...
    session = TrainSession.restore_latest("ckpt/run1", model, client_data)
    session.train(rounds=100)            # continues round 100..199
    session.evaluate(x_test, y_test)

See docs/API.md for the full lifecycle and the checkpoint layout.
"""
from __future__ import annotations

import dataclasses
import glob as _glob
import json
import os
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import fused_engine as _fused_engine      # noqa: F401 (registers)
from repro.api import reference_engine as _reference_engine  # noqa: F401
from repro.api import spmd_engine as _spmd_engine        # noqa: F401
from repro.api.engines import SessionContext, resolve_engine
from repro.api.evaluation import SplitEvaluator
from repro.api.protocol import assert_split_model
from repro.api.state import TrainState, init_train_state
from repro.checkpoint import load_pytree, save_pytree
from repro.config import HeteroProfile, OptimizerConfig, SplitEEConfig
from repro.core.strategies import RoundMetrics
from repro.launch.shardings import recipe_from_meta, recipe_to_meta

#: checkpoint manifest format version (bump on layout changes)
CHECKPOINT_FORMAT = 1


def _model_name(model) -> str:
    """Adapter identity recorded in checkpoint manifests: the adapter's
    ``name`` (BackboneSplitModel reports its arch config name) or the
    adapter class name for the paper-scale MLP/ResNet adapters."""
    return str(getattr(model, "name", type(model).__name__))


class TrainSession:
    """Facade over (model adapter, configs, data, engine, TrainState)."""

    def __init__(self, model, splitee_cfg: SplitEEConfig,
                 opt_cfg: OptimizerConfig,
                 client_data: Sequence[Tuple[np.ndarray, np.ndarray]],
                 batch_size: int, *, engine: str = "auto",
                 augment=None, seed: int = 0,
                 mesh=None, grad_mode: str = "eq1", recipe=None,
                 state: Optional[TrainState] = None,
                 history: Optional[List[RoundMetrics]] = None):
        assert_split_model(model)
        self.ctx = SessionContext(model, splitee_cfg, opt_cfg, client_data,
                                  batch_size, augment=augment, seed=seed,
                                  mesh=mesh, grad_mode=grad_mode,
                                  recipe=recipe)
        engine_cls, self._engine_note = resolve_engine(engine, self.ctx)
        self.engine = engine_cls(self.ctx)
        self.state = (state if state is not None
                      else init_train_state(model, splitee_cfg, opt_cfg))
        self.history: List[RoundMetrics] = list(history or [])
        self._evaluator = SplitEvaluator(model, self.ctx.profile,
                                         self.ctx.strategy)

    @classmethod
    def from_config(cls, model, splitee_cfg: SplitEEConfig,
                    opt_cfg: OptimizerConfig,
                    data: Sequence[Tuple[np.ndarray, np.ndarray]],
                    batch_size: int = 64, *, engine: str = "auto",
                    augment=None, seed: int = 0,
                    mesh=None, grad_mode: str = "eq1",
                    recipe=None) -> "TrainSession":
        """The canonical constructor (same arguments as ``__init__``; named
        for symmetry with ``restore``).  ``mesh`` selects the device mesh
        for the spmd engine (and makes it eligible under ``engine="auto"``);
        ``grad_mode`` is ``"eq1"`` (paper-faithful) or ``"sum"`` (single
        fused backward; averaging engines only); ``recipe`` is the spmd
        engine's sharding recipe — a ``launch.shardings.NAMED_RECIPES``
        name (``"greedy"`` default, ``"megatron"``, ``"fsdp-off"``,
        ``"replicate"``, ...) or a ``ShardingRecipe`` instance."""
        return cls(model, splitee_cfg, opt_cfg, data, batch_size,
                   engine=engine, augment=augment, seed=seed, mesh=mesh,
                   grad_mode=grad_mode, recipe=recipe)

    # ---------------------------------------------------------- properties
    @property
    def model(self):
        return self.ctx.model

    @property
    def round(self) -> int:
        """Global rounds completed so far."""
        return int(self.state.round)

    @property
    def engine_name(self) -> str:
        """The selected engine, annotated with *why* wider candidates were
        skipped when ``engine="auto"`` resolved the choice — e.g.
        ``"fused (spmd unavailable: ... only 1 device visible)"`` — so
        benchmark manifests and logs record the real execution path.  Use
        ``session.engine.name`` for the bare registry name."""
        if self._engine_note:
            return f"{self.engine.name} ({self._engine_note})"
        return self.engine.name

    # ------------------------------------------------------------ training
    def train(self, rounds: int, local_epochs: int = 1, log_every: int = 0,
              chunk_rounds: int = 0, *, save_every: int = 0,
              save_dir: Optional[str] = None,
              keep_last: int = 3) -> List[RoundMetrics]:
        """Advance the state by ``rounds`` rounds; returns the new rounds'
        metrics (also appended to ``self.history``).

        ``save_every=N`` checkpoints into ``save_dir`` every N rounds (and
        once more at the end when ``rounds`` is not a multiple), rotating
        so only the newest ``keep_last`` checkpoints remain on disk; pick
        the run back up with :meth:`restore_latest`."""
        if save_every < 0 or (save_every and not save_dir):
            raise ValueError("save_every needs save_dir (and save_every "
                             f">= 0); got save_every={save_every} "
                             f"save_dir={save_dir!r}")
        if not save_every:
            return self._train_segment(rounds, local_epochs, log_every,
                                       chunk_rounds)
        metrics: List[RoundMetrics] = []
        done = 0
        while done < rounds:
            n = min(save_every, rounds - done)
            metrics.extend(self._train_segment(n, local_epochs, log_every,
                                               chunk_rounds))
            done += n
            self._save_rotating(save_dir, keep_last)
        return metrics

    def _train_segment(self, rounds, local_epochs, log_every, chunk_rounds
                       ) -> List[RoundMetrics]:
        self.state, metrics = self.engine.run(
            self.state, rounds, local_epochs=local_epochs,
            log_every=log_every, chunk_rounds=chunk_rounds)
        self.history.extend(metrics)
        return metrics

    def run(self, rounds: int, local_epochs: int = 1, log_every: int = 0,
            chunk_rounds: int = 0) -> List[RoundMetrics]:
        """Back-compat alias for :meth:`train` returning the full history
        (the pre-facade trainer ``run`` contract)."""
        self.train(rounds, local_epochs, log_every, chunk_rounds)
        return self.history

    # ---------------------------------------------------------- evaluation
    def evaluate(self, x, y, batch_size: int = 512) -> Dict[str, Any]:
        return self._evaluator.evaluate(self.state, x, y, batch_size)

    def evaluate_adaptive(self, x, y, tau: float, batch_size: int = 512
                          ) -> Dict[str, Any]:
        return self._evaluator.evaluate_adaptive(self.state, x, y, tau,
                                                 batch_size)

    # -------------------------------------------------------- checkpointing
    def save(self, path: str) -> None:
        """Write ``path + '.npz'`` (the full TrainState pytree) and
        ``path + '.json'`` (structure manifest + session metadata).  The
        model adapter and the data shards are NOT serialized — pass the
        same ones to :meth:`restore`."""
        opt = dataclasses.asdict(self.ctx.opt_cfg)
        opt["state_dtype"] = jnp.dtype(opt["state_dtype"]).name
        meta = {
            "format": CHECKPOINT_FORMAT,
            "kind": "train_session",
            "engine": self.engine.name,
            # adapter identity (e.g. BackboneSplitModel exposes the arch
            # config name): restore refuses a different model so a state is
            # never silently loaded into another architecture
            "model": _model_name(self.ctx.model),
            "splitee": {
                "split_layers": list(self.ctx.profile.split_layers),
                "strategy": self.ctx.cfg.strategy,
                "server_lr_divisor": self.ctx.cfg.server_lr_divisor,
                "aggregate_every": self.ctx.cfg.aggregate_every,
                "entropy_threshold": self.ctx.cfg.entropy_threshold,
            },
            "optimizer": opt,
            "grad_mode": self.ctx.grad_mode,
            # the spmd sharding recipe is layout, not math: recorded for
            # auditability, and restore reshards transparently under
            # whatever recipe the restoring session runs (cross-recipe
            # resume is equivalence-tested)
            "recipe": {"name": self.ctx.recipe_name,
                       **recipe_to_meta(self.ctx.recipe)},
            # kernel backend is likewise layout, not math: recorded for
            # auditability only; restore runs whatever the restoring
            # model's config selects
            "kernels": getattr(getattr(self.ctx.model, "cfg", None),
                               "kernels", None),
            "batch_size": self.ctx.batch_size,
            "seed": self.ctx.seed,
            # the augment callable itself is not serializable, but whether
            # one was active is: the data replay diverges if it differs
            "augmented": self.ctx.augment is not None,
            "round": self.round,
            "history": [dataclasses.asdict(m) for m in self.history],
        }
        save_pytree(path, self.state, metadata=meta)

    def _save_rotating(self, save_dir: str, keep_last: int) -> None:
        """``save_dir/ckpt-<round>`` plus keep-last-``keep_last`` rotation
        (oldest ``.npz``/``.json`` pairs beyond the budget are removed).

        Under a multi-process (``jax.distributed``) run every rank calls
        this — :meth:`train`'s ``save_every`` segmentation must dispatch
        the identical jit/collective sequence on every process — but only
        process 0 touches the shared filesystem."""
        if jax.process_index() != 0:
            return
        os.makedirs(save_dir, exist_ok=True)
        self.save(os.path.join(save_dir, f"ckpt-{self.round:08d}"))
        stems = sorted(p[:-5] for p in
                       _glob.glob(os.path.join(save_dir, "ckpt-*.json")))
        for stem in stems[:-max(1, keep_last)]:
            for ext in (".npz", ".json"):
                try:
                    os.remove(stem + ext)
                except FileNotFoundError:
                    pass

    @classmethod
    def restore_latest(cls, save_dir: str, model,
                       client_data: Sequence[Tuple[np.ndarray, np.ndarray]],
                       *, engine: Optional[str] = None, augment=None,
                       mesh=None, recipe=None) -> "TrainSession":
        """Resume from the newest *readable* checkpoint under ``save_dir``
        (the layout :meth:`train`'s ``save_every`` writes).  Checkpoints
        are tried newest-first; a truncated or unreadable pair (a crash
        mid-save) is skipped with a warning.  Only read/parse failures are
        skipped — a checkpoint that loads but cannot build a session (bad
        engine for this host, config mismatch) raises, so configuration
        errors are never misreported as corruption."""
        stems = sorted((p[:-5] for p in
                        _glob.glob(os.path.join(save_dir, "ckpt-*.json"))),
                       reverse=True)
        errors = []
        for stem in stems:
            try:
                with open(stem + ".json") as f:
                    json.load(f)
                np.load(stem + ".npz").close()
            except Exception as e:                        # noqa: BLE001
                warnings.warn(f"skipping unreadable checkpoint {stem}: {e}")
                errors.append(f"{os.path.basename(stem)}: {e}")
                continue
            return cls.restore(stem, model, client_data, engine=engine,
                               augment=augment, mesh=mesh, recipe=recipe)
        detail = f" (tried: {'; '.join(errors)})" if errors else ""
        raise FileNotFoundError(
            f"no readable TrainSession checkpoint under "
            f"{save_dir!r}{detail}")

    @classmethod
    def restore(cls, path: str, model,
                client_data: Sequence[Tuple[np.ndarray, np.ndarray]],
                *, engine: Optional[str] = None, augment=None,
                mesh=None, recipe=None) -> "TrainSession":
        """Rebuild a session from :meth:`save` output.  Configuration comes
        from the manifest; ``model`` and ``client_data`` must be the ones
        the run was built with (the state carries every learned tensor, the
        adapter only its architecture/seed).  ``engine`` overrides the saved
        engine name — a state saved by one engine restores into any other
        that supports the strategy.  ``mesh`` (not serializable) must be
        re-supplied when the spmd engine should run on a specific mesh.
        ``recipe`` overrides the saved sharding recipe — recipes are layout,
        not math, so a state saved under one reshards transparently into
        another (the checkpoint holds host arrays; the restoring engine
        places them per its own recipe)."""
        with open(path + ".json") as f:
            meta = json.load(f)["metadata"]
        if meta.get("kind") != "train_session":
            raise ValueError(f"{path} is not a TrainSession checkpoint")
        if meta.get("format") != CHECKPOINT_FORMAT:
            raise ValueError(
                f"{path} has checkpoint format {meta.get('format')!r}; this "
                f"version reads format {CHECKPOINT_FORMAT}")
        saved_model = meta.get("model")          # absent in older manifests
        if saved_model is not None and saved_model != _model_name(model):
            raise ValueError(
                f"checkpoint was saved with model {saved_model!r} but "
                f"restore got {_model_name(model)!r}; the state cannot be "
                f"loaded into a different architecture")
        if meta["augmented"] != (augment is not None):
            raise ValueError(
                f"checkpoint was saved with augment "
                f"{'active' if meta['augmented'] else 'inactive'} but "
                f"restore got augment={augment!r}; the replayed data stream "
                f"would diverge — pass the original augment function")
        sp = meta["splitee"]
        splitee_cfg = SplitEEConfig(
            profile=HeteroProfile(tuple(sp["split_layers"])),
            strategy=sp["strategy"],
            server_lr_divisor=sp["server_lr_divisor"],
            aggregate_every=sp["aggregate_every"],
            entropy_threshold=sp["entropy_threshold"])
        opt = dict(meta["optimizer"])
        opt["state_dtype"] = jnp.dtype(opt["state_dtype"])
        opt_cfg = OptimizerConfig(**opt)
        if recipe is None and "recipe" in meta:
            saved = dict(meta["recipe"])
            name = saved.pop("name", "custom")
            recipe = (name if name != "custom"
                      else recipe_from_meta(saved))
        session = cls(model, splitee_cfg, opt_cfg, client_data,
                      meta["batch_size"], engine=engine or meta["engine"],
                      augment=augment, seed=meta["seed"], mesh=mesh,
                      grad_mode=meta.get("grad_mode", "eq1"),
                      recipe=recipe)
        # fresh init has the identical pytree structure: restore into it
        session.state = load_pytree(path, session.state)
        session.history = [RoundMetrics(**m) for m in meta["history"]]
        return session
