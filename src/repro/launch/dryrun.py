import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  ``--host-devices N`` (for local testing) is honored
# by rewriting the flag before jax is imported.
import sys

if "--host-devices" in sys.argv:
    _n = sys.argv[sys.argv.index("--host-devices") + 1]
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_n}"

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import configs as configs_mod
from repro.config import (INPUT_SHAPES, SHAPES_BY_NAME, ModelConfig,
                          OptimizerConfig, ShapeConfig, SplitEEConfig,
                          TrainConfig)
from repro.core.spmd import (StepConfig, boundary_ids_for_batch,
                             make_serve_step, make_train_step)
from repro.core.losses import softmax_entropy
from repro.launch import shardings as sh
from repro.launch.hlo_analysis import analyze as hlo_analyze
from repro.launch.inputs import (abstract_params, serve_input_specs,
                                 train_input_specs)
from repro.launch.mesh import make_production_mesh
from repro.models.backbone import backbone_forward
from repro.optim import adam_init

# ---------------------------------------------------------------------------
# long-context policy (docs/DESIGN.md §4): SSM/hybrid run natively; dense archs
# get a 4096-token sliding-window variant; whisper is skipped (documented).
# ---------------------------------------------------------------------------
LONG_SWA_WINDOW = 4096
LONG_NATIVE = {"zamba2-1.2b", "rwkv6-3b"}
LONG_SKIP = {"whisper-small"}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum operand sizes of every collective op in the (post-SPMD) HLO.
    Operands are the shape tokens after the '= opcode(' on the op line; the
    result shape (before '=') is excluded."""
    totals = {c: 0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.find("= ")
        if eq < 0:
            continue
        rhs = s[eq + 2:]
        for c in COLLECTIVES:
            # match opcode at the start of the rhs (e.g. "all-reduce(" or
            # "bf16[..] all-reduce(..)") excluding -start/-done variants of
            # async pairs (count the -start only to avoid double counting).
            if re.search(rf"\b{c}(-start)?\(", rhs) and f"{c}-done" not in rhs:
                paren = rhs.find("(")
                ops = rhs[paren:]
                b = sum(_shape_bytes(m) for m in _SHAPE_RE.finditer(ops))
                totals[c] += b
                counts[c] += 1
                break
    totals["total"] = sum(totals[c] for c in COLLECTIVES)
    counts["total"] = sum(counts[c] for c in COLLECTIVES)
    return {"bytes": totals, "counts": counts}


def arch_config(arch: str, shape_name: str) -> Optional[ModelConfig]:
    mod = configs_mod.get(arch)
    if shape_name == "long_500k":
        name = mod.config().name
        if name in LONG_SKIP:
            return None
        if name in LONG_NATIVE:
            return mod.config()
        return mod.config(sliding_window=LONG_SWA_WINDOW)
    return mod.config()


def build_step_and_args(cfg: ModelConfig, shape: ShapeConfig, mesh,
                        profile, *, grad_mode: str = "eq1",
                        remat: str = "full",
                        recipe: Optional[sh.ShardingRecipe] = None,
                        last_token_heads: bool = False):
    """Returns (jitted_fn, abstract_args) ready to ``.lower()``."""
    recipe = recipe or sh.default_recipe(cfg, mesh)
    params_abs = abstract_params(cfg)
    pspecs = sh.param_specs(params_abs, cfg, mesh, recipe)
    psh = sh.to_named(pspecs, mesh)

    sc = StepConfig(
        model=cfg,
        splitee=SplitEEConfig(profile=profile),
        train=TrainConfig(seq_len=shape.seq_len, batch_size=shape.global_batch,
                          remat=remat,
                          optimizer=OptimizerConfig(
                              state_dtype=jnp.bfloat16,
                              total_steps=10_000)),
        grad_mode=grad_mode)

    if shape.kind == "train":
        specs = train_input_specs(cfg, shape)
        bsh = sh.to_named(sh.batch_specs(specs, mesh), mesh)
        opt_abs = jax.eval_shape(
            lambda p: adam_init(p, sc.train.optimizer), params_abs)
        step = make_train_step(sc)
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.optim import AdamState
        opt_in_sh = AdamState(step=NamedSharding(mesh, P()), m=psh, v=psh)
        fn = jax.jit(step,
                     in_shardings=(psh, opt_in_sh, bsh),
                     out_shardings=(psh, opt_in_sh, None))
        return fn, (params_abs, opt_abs, specs)

    if shape.kind == "prefill":
        specs = train_input_specs(cfg, shape)
        specs.pop("labels")
        bsh = sh.to_named(sh.batch_specs(specs, mesh), mesh)

        def prefill_step(params, batch):
            out = backbone_forward(params, cfg, tokens=batch.get("tokens"),
                                   embeds=batch.get("embeds"),
                                   enc=batch.get("enc"),
                                   split_ids=batch["split_ids"])
            if last_token_heads:
                # serving prefill needs only the next-token position; full
                # (B,T,V) exit/final logits were the peak-memory term
                # (§Perf iteration 3)
                ent = [softmax_entropy(e[:, -1:]) for e in out.exit_logits]
                return {"logits": out.logits[:, -1:],
                        "exit_entropy": jnp.stack(ent) if ent else None}
            ent = [softmax_entropy(e) for e in out.exit_logits]
            return {"logits": out.logits,
                    "exit_entropy": jnp.stack(ent) if ent else None}

        fn = jax.jit(prefill_step, in_shardings=(psh, bsh))
        return fn, (params_abs, specs)

    # decode
    specs = serve_input_specs(cfg, shape)
    csh = sh.to_named(sh.cache_specs(specs["cache"], cfg, mesh, recipe), mesh)
    bsh = {"tokens": sh.to_named(sh.batch_specs(
        {"tokens": specs["tokens"]}, mesh), mesh)["tokens"],
        "cache": csh,
        "cache_len": sh.to_named(sh.batch_specs(
            {"c": specs["cache_len"]}, mesh), mesh)["c"]}
    serve = make_serve_step(sc, boundary=0)

    if cfg.arch_type == "audio":
        enc_sh = sh.to_named(sh.batch_specs({"enc": specs["enc"]}, mesh),
                             mesh)["enc"]

        def fn_step(params, tokens, cache, cache_len, enc):
            return serve(params, tokens, cache, cache_len, enc=enc)

        fn = jax.jit(fn_step, in_shardings=(psh, bsh["tokens"], csh,
                                            bsh["cache_len"], enc_sh))
        return fn, (params_abs, specs["tokens"], specs["cache"],
                    specs["cache_len"], specs["enc"])

    fn = jax.jit(serve, in_shardings=(psh, bsh["tokens"], csh,
                                      bsh["cache_len"]))
    return fn, (params_abs, specs["tokens"], specs["cache"],
                specs["cache_len"])


def run_one(arch: str, shape_name: str, multi_pod: bool, *,
            grad_mode: str = "eq1", remat: str = "full",
            recipe: Optional[sh.ShardingRecipe] = None,
            last_token_heads: bool = False,
            mesh=None) -> Dict[str, Any]:
    shape = SHAPES_BY_NAME[shape_name]
    cfg = arch_config(arch, shape_name)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": "multi_pod" if multi_pod else "single_pod",
                           "kind": shape.kind, "grad_mode": grad_mode,
                           "remat": remat,
                           "recipe": recipe.scheme if recipe else "greedy"}
    if cfg is None:
        rec["status"] = "skipped"
        rec["reason"] = "long_500k inapplicable (see docs/DESIGN.md §4)"
        return rec
    mod = configs_mod.get(arch)
    profile = mod.profile()
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)

    rec["last_token_heads"] = last_token_heads
    t0 = time.time()
    fn, args = build_step_and_args(cfg, shape, mesh, profile,
                                   grad_mode=grad_mode, remat=remat,
                                   recipe=recipe,
                                   last_token_heads=last_token_heads)
    from repro.models import sharding_ctx
    with mesh, sharding_ctx.activation_sharding(mesh):
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "peak_memory_bytes": int(getattr(mem, "peak_memory_in_bytes", 0)),
            "argument_size_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_size_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_size_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_size_bytes":
                int(getattr(mem, "generated_code_size_in_bytes", 0)),
        }
    except Exception as e:                                    # noqa: BLE001
        rec["memory"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        rec["cost"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float)) and
                       k in ("flops", "bytes accessed", "optimal_seconds",
                             "utilization operand 0", "bytes accessed output")}
        rec["flops"] = float(cost.get("flops", 0.0))
        rec["bytes_accessed"] = float(cost.get("bytes accessed", 0.0))
    except Exception as e:                                    # noqa: BLE001
        rec["cost"] = {"error": str(e)}

    hlo = compiled.as_text()
    # trip-count-aware analysis (per-device numbers; scans expanded)
    ana = hlo_analyze(hlo)
    rec["analysis"] = {
        "flops_per_device": ana["flops"],
        "hbm_bytes_per_device": ana["hbm_bytes"],
        "collective_bytes_per_device": ana["collective_bytes"],
        "collective_total_per_device": ana["collective_total"],
    }
    rec["collectives"] = collective_bytes(hlo)   # naive (bodies counted once)
    rec["hlo_bytes"] = len(hlo)
    rec["lower_s"] = round(t_lower, 2)
    rec["compile_s"] = round(t_compile, 2)
    rec["status"] = "ok"
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--grad-mode", default="eq1", choices=["eq1", "sum"])
    ap.add_argument("--remat", default="full", choices=["full", "none"])
    ap.add_argument("--recipe", default="greedy",
                    choices=["greedy", "megatron", "megatron-nofsdp",
                             "hybrid"])
    ap.add_argument("--last-token-heads", action="store_true")
    ap.add_argument("--fsdp-pod", action="store_true",
                    help="3-axis FSDP: shard params/optimizer over "
                         "('pod','data') — multi-pod mesh only")
    ap.add_argument("--out", default="")
    ap.add_argument("--host-devices", default="512")  # consumed pre-import
    args = ap.parse_args()

    archs = configs_mod.all_arch_ids() if args.arch == "all" else [args.arch]
    shapes = ([s.name for s in INPUT_SHAPES] if args.shape == "all"
              else [args.shape])
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    recipe = {
        "greedy": None,
        "megatron": sh.ShardingRecipe(scheme="megatron"),
        "megatron-nofsdp": sh.ShardingRecipe(scheme="megatron", fsdp=False),
        "hybrid": sh.ShardingRecipe(scheme="hybrid"),
    }[args.recipe]
    if args.fsdp_pod:
        base = recipe or sh.ShardingRecipe()
        import dataclasses as _dc
        recipe = _dc.replace(base, fsdp_axes=("pod", "data"))

    out_f = open(args.out, "a") if args.out else None
    n_devices = len(jax.devices())
    print(f"# dry-run on {n_devices} host devices (recipe={args.recipe})")
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                tag = f"{arch} x {shape} x {'multi' if multi_pod else 'single'}"
                try:
                    rec = run_one(arch, shape, multi_pod,
                                  grad_mode=args.grad_mode, remat=args.remat,
                                  recipe=recipe,
                                  last_token_heads=args.last_token_heads)
                except Exception:                             # noqa: BLE001
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi_pod" if multi_pod else "single_pod",
                           "grad_mode": args.grad_mode,
                           "status": "error",
                           "error": traceback.format_exc(limit=25)}
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f" flops={rec.get('flops', 0):.3e}"
                             f" coll={rec['collectives']['bytes']['total']:.3e}"
                             f" compile={rec['compile_s']}s")
                print(f"[{status:7s}] {tag}{extra}", flush=True)
                if out_f:
                    out_f.write(json.dumps(rec) + "\n")
                    out_f.flush()
    if out_f:
        out_f.close()


if __name__ == "__main__":
    main()
