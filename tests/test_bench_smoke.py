"""Smoke-test the fused benchmark end-to-end at CI size: two tiny rounds per
engine, then validate the emitted ``BENCH_fused.json`` schema so the
benchmark can't silently rot."""
import json
import os

import pytest

from benchmarks import fused_vs_reference


def test_fused_benchmark_emits_valid_json(tmp_path):
    out = os.path.join(tmp_path, "BENCH_fused.json")
    rows = fused_vs_reference.run(rounds=2, clients=4, batch_size=32, out=out)

    # rows consumable by benchmarks/run.py's CSV emitter
    assert len(rows) == 2
    for r in rows:
        assert set(("name", "us_per_call", "derived")) <= set(r)

    with open(out) as f:
        data = json.load(f)
    assert set(fused_vs_reference.SCHEMA_KEYS) <= set(data)
    assert data["benchmark"] == "fused_vs_reference"
    assert data["config"]["clients"] == 4
    assert len(data["config"]["splits"]) == 4
    for eng in ("reference", "fused"):
        assert data[eng]["wall_s"] > 0
        assert data[eng]["rounds_per_sec"] > 0
    assert data["speedup"] == pytest.approx(
        data["reference"]["wall_s"] / data["fused"]["wall_s"])
    # engines trained on identical minibatches: metrics must agree
    assert data["max_metric_delta"] < 1e-4
