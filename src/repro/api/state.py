"""``TrainState`` — the complete training state as one immutable pytree.

Everything a Hetero-SplitEE run accumulates lives here: per-client nets and
Adam moments, per-server nets and moments, the global round counter, and the
per-client data-iterator cursors.  Engines (api/engines.py) are pure
``state -> state`` executors over this type; checkpointing is
``checkpoint.save_pytree(path, state)`` plus a restore into a structurally
identical fresh state — there is no hidden trainer-attribute state anywhere.

Layout (see docs/API.md):

  * ``clients[i]``      — ``{"trainable": ..., "state": ...}`` for client i
  * ``client_opts[i]``  — ``AdamState`` for client i
  * ``servers[j]``      — server nets: one shared entry for the Sequential
    strategy, one per client for Averaging / distributed
  * ``server_opts[j]``  — ``AdamState`` per server entry
  * ``round``           — int32 scalar, global rounds completed
  * ``batches_drawn``   — int32 ``[N]``, minibatches drawn per client; on
    restore the session replays each seeded ``batch_iterator`` to this
    cursor so the resumed run consumes the exact upcoming batch sequence
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig, SplitEEConfig
from repro.optim import AdamState, adam_init


@dataclass(frozen=True)
class TrainState:
    clients: Tuple[Any, ...]
    client_opts: Tuple[AdamState, ...]
    servers: Tuple[Any, ...]
    server_opts: Tuple[AdamState, ...]
    round: jnp.ndarray            # int32 scalar
    batches_drawn: jnp.ndarray    # int32 [num_clients]

    @property
    def num_clients(self) -> int:
        return len(self.clients)

    def replace(self, **kw) -> "TrainState":
        return dataclasses.replace(self, **kw)


_FIELDS = ("clients", "client_opts", "servers", "server_opts", "round",
           "batches_drawn")

jax.tree_util.register_pytree_with_keys(
    TrainState,
    lambda s: (tuple((jax.tree_util.GetAttrKey(f), getattr(s, f))
                     for f in _FIELDS), None),
    lambda _, children: TrainState(*children),
    flatten_func=lambda s: (tuple(getattr(s, f) for f in _FIELDS), None),
)


def init_train_state(model, splitee_cfg: SplitEEConfig,
                     opt_cfg: OptimizerConfig) -> TrainState:
    """Round-zero state: all nets initialized from the model adapter's seed
    (paper §III-B — common layers start identical across clients)."""
    profile = splitee_cfg.profile
    splits = profile.split_layers
    clients = tuple(model.make_client(li) for li in splits)
    client_opts = tuple(adam_init(c["trainable"], opt_cfg) for c in clients)

    if splitee_cfg.strategy == "sequential":
        shared = model.make_server(min(splits))      # one shared server model
        servers = (shared,)
        server_opts = (adam_init(shared["trainable"], opt_cfg),)
    elif splitee_cfg.strategy in ("averaging", "distributed"):
        servers = tuple(model.make_server(li) for li in splits)
        server_opts = tuple(adam_init(s["trainable"], opt_cfg)
                            for s in servers)
    else:
        raise ValueError(f"unknown strategy {splitee_cfg.strategy!r}")

    return TrainState(
        clients=clients, client_opts=client_opts,
        servers=servers, server_opts=server_opts,
        round=jnp.zeros((), jnp.int32),
        batches_drawn=jnp.zeros((profile.num_groups,), jnp.int32))
