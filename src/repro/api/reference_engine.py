"""Reference engine: the paper-faithful per-client loop as a pure
``TrainState -> TrainState`` executor.

Literally Alg. 1 / Alg. 2: per round, each client runs E local minibatch
steps (client-side loss on its exit head) and the server performs one update
per transmitted minibatch — the shared server under Sequential (server LR
divided by N, paper Table II), per-client servers under Averaging /
distributed, with Eq. (1) cross-layer aggregation on Averaging boundaries.
Gradients never flow from server to client (``h`` enters the server step
through ``stop_gradient``).

One jitted client step and one jitted server step per split layer, a
``float(loss)`` host sync per minibatch: slow but literal — every behavioral
question about other engines is settled against this one.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.engines import Engine, SessionContext, register_engine
from repro.api.state import TrainState
from repro.core.aggregation import cross_layer_aggregate
from repro.core.strategies import (RoundMetrics, make_client_step,
                                   make_server_step)


@register_engine("reference")
class ReferenceEngine(Engine):

    def __init__(self, ctx: SessionContext):
        super().__init__(ctx)
        self._cstep: Dict[int, Callable] = {}
        self._sstep: Dict[int, Callable] = {}

    @classmethod
    def supports(cls, ctx: SessionContext):
        if ctx.strategy not in ("sequential", "averaging", "distributed"):
            return f"unknown strategy {ctx.strategy!r}"
        if ctx.grad_mode != "eq1":
            return (f"the reference engine implements the paper-faithful "
                    f"'eq1' gradient routing only, not {ctx.grad_mode!r} — "
                    f"use the fused or spmd engine for 'sum'")
        return None

    # ------------------------------------------------------------------ jit
    def _client_step(self) -> Callable:
        # the client step is li-independent (the trainable's own layer keys
        # determine depth), so one jitted step serves every cohort
        if 0 not in self._cstep:
            self._cstep[0] = jax.jit(make_client_step(self.ctx.model,
                                                      self.ctx.opt_cfg))
        return self._cstep[0]

    def _server_step(self, li: int) -> Callable:
        if li not in self._sstep:
            self._sstep[li] = jax.jit(make_server_step(self.ctx.model,
                                                       self.ctx.opt_cfg, li))
        return self._sstep[li]

    # ------------------------------------------------------------ training
    def run(self, state: TrainState, rounds: int, local_epochs: int = 1,
            log_every: int = 0, chunk_rounds: int = 0
            ) -> Tuple[TrainState, List]:
        """``chunk_rounds`` is accepted for engine-interface uniformity and
        ignored — the reference engine is round-by-round by construction."""
        ctx = self.ctx
        ctx.data.align(state.batches_drawn)
        clients, copts = list(state.clients), list(state.client_opts)
        servers, sopts = list(state.servers), list(state.server_opts)
        t0 = int(state.round)
        metrics: List[RoundMetrics] = []

        for r in range(rounds):
            t = t0 + r
            lr = ctx.schedule(t)
            lr_server = lr / ctx.server_lr_div
            closses, slosses = [], []

            for i, li in enumerate(ctx.profile.split_layers):
                cstep = self._client_step()
                sstep = self._server_step(li)
                sidx = 0 if ctx.strategy == "sequential" else i
                client, copt = clients[i], copts[i]
                server, sopt = servers[sidx], sopts[sidx]

                for _ in range(local_epochs):
                    x, y = ctx.data.draw(i)
                    x, y = jnp.asarray(x), jnp.asarray(y)
                    # client-side training (Alg. 1/2 lines 6-11)
                    tr, st, copt, h, closs = cstep(client["trainable"],
                                                   client["state"], copt,
                                                   x, y, lr)
                    client = {"trainable": tr, "state": st}
                    # server-side training on h_i (lines 12-16)
                    h = jax.lax.stop_gradient(h)
                    str_, sst, sopt, sloss = sstep(server["trainable"],
                                                   server["state"], sopt,
                                                   h, y, lr_server)
                    server = {"trainable": str_, "state": sst}
                    closses.append(float(closs))
                    slosses.append(float(sloss))

                clients[i], copts[i] = client, copt
                servers[sidx], sopts[sidx] = server, sopt

            # cross-layer aggregation (Alg. 2 lines 20-30)
            if (ctx.strategy == "averaging"
                    and (t + 1) % ctx.cfg.aggregate_every == 0):
                splits = list(ctx.profile.split_layers)
                trainables = cross_layer_aggregate(
                    [s["trainable"] for s in servers], splits)
                states = cross_layer_aggregate(
                    [s["state"] for s in servers], splits,
                    extra_shared_keys=())
                servers = [{"trainable": tr, "state": st}
                           for tr, st in zip(trainables, states)]

            m = RoundMetrics(t, float(np.mean(closses)),
                             float(np.mean(slosses)))
            metrics.append(m)
            if log_every and (t % log_every == 0):
                print(f"round {t:4d}  client_loss {m.client_loss:.4f}  "
                      f"server_loss {m.server_loss:.4f}")

        new_state = state.replace(
            clients=tuple(clients), client_opts=tuple(copts),
            servers=tuple(servers), server_opts=tuple(sopts),
            round=jnp.asarray(t0 + rounds, jnp.int32),
            batches_drawn=state.batches_drawn
            + jnp.asarray(rounds * local_epochs, jnp.int32))
        return new_state, metrics
