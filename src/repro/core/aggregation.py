"""Cross-layer aggregation — paper Eq. (1).

For every layer ``l`` of the full network, the participation set
``C_l = {i | l_i < l}`` (clients whose *server-side* model contains layer l)
averages its parameters; the mean is broadcast back to every member.  Models
are dicts keyed by layer name (``layer4``, ``head``, ...) so "common layers"
are identified by key across heterogeneous server models.

Two implementations:
  * ``cross_layer_aggregate``      — literal per-client loop (the reference,
    used by the paper-faithful Averaging strategy and by the test oracle).
  * ``masked_mean_over_axis``      — the SPMD collective form: a weighted
    ``psum`` over a mesh axis with per-layer participation masks, used by the
    production fused step (see core/spmd.py and DESIGN.md §2).
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp


def _mean_trees(trees: Sequence[Any]) -> Any:
    n = float(len(trees))
    return jax.tree.map(lambda *xs: sum(x.astype(jnp.float32) for x in xs)
                        .astype(xs[0].dtype) / n, *trees)


def cross_layer_aggregate(server_models: Sequence[Dict[str, Any]],
                          split_layers: Sequence[int],
                          extra_shared_keys: Sequence[str] = ("head",),
                          ) -> List[Dict[str, Any]]:
    """Aggregate client-specific server models (Alg. 2 lines 20-30).

    server_models[i] is a dict whose keys are the layers client i's server
    model contains: ``layer{l}`` for l in (l_i, L] (1-indexed, paper naming)
    plus the keys in ``extra_shared_keys`` which every server model has.
    Returns NEW server models with common layers replaced by the mean.
    """
    assert len(server_models) == len(split_layers)
    out = [dict(m) for m in server_models]

    all_keys = set()
    for m in server_models:
        all_keys |= set(m.keys())

    for key in sorted(all_keys):
        members = [i for i, m in enumerate(server_models) if key in m]
        if len(members) <= 1:
            continue
        mean = _mean_trees([server_models[i][key] for i in members])
        for i in members:
            out[i][key] = mean
    return out


def participation_counts(split_layers: Sequence[int], num_layers: int):
    """For each 0-indexed layer l: (#clients with l client-side,
    #clients with l server-side).  Client i holds layers [0, l_i)."""
    n_client = [sum(1 for s in split_layers if l < s) for l in range(num_layers)]
    n_server = [len(split_layers) - c for c in n_client]
    return n_client, n_server


def masked_mean_over_axis(value: jnp.ndarray, participate: jnp.ndarray,
                          axis_name: str) -> jnp.ndarray:
    """SPMD Eq. (1): mean of ``value`` over the mesh axis restricted to
    shards where ``participate`` (0/1 scalar) is set.  The mean is broadcast
    back to the members of C_l only (paper Alg. 2 line 25); non-members keep
    their value unchanged."""
    num = jax.lax.psum(value * participate, axis_name)
    den = jax.lax.psum(participate, axis_name)
    mean = num / jnp.maximum(den, 1.0)
    return jnp.where((participate > 0) & (den > 0), mean, value)
