"""Paper Fig. 2: sensitivity of collaborative inference to the confidence
threshold.  Trains the Sequential strategy on the learnable 10-class
dataset (syn10 default, homogeneous clients — see ``run`` for why the hard
syn100 stand-in is not used here), then sweeps the entropy threshold and
records accuracy + client adoption ratio + mean entropy per split depth."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import make_dataset, run_strategy
from repro.core.inference import H_CAP


def run(rounds: int = 40, train_size: int = 1200, test_size: int = 384,
        layers=(3, 4, 5), n_clients: int = 6, num_taus: int = 17,
        dataset: str = "syn10", seed: int = 0) -> List[dict]:
    """Paper Fig. 2 uses CIFAR-100; at this container's reduced training
    budget the 100-class exits stay uniformly unconfident (H ~ ln 100), so
    the sweep is demonstrated on the learnable 10-class stand-in where the
    entropy gate actually discriminates (see docs/EXPERIMENTS.md)."""
    rows = []
    ds = make_dataset(dataset, train_size, test_size, seed=seed)
    # paper sweeps tau in [0, 4] at 0.05 granularity; we use a coarser grid
    # over the same range (tau here is the ENTROPY threshold tau_H; the
    # paper's conservativeness axis is H_CAP - tau_H, docs/DESIGN.md §1).
    taus = np.linspace(0.0, H_CAP, num_taus)
    for layer in layers:
        splits = (layer,) * n_clients
        ev = run_strategy(ds, "sequential", splits, rounds=rounds, seed=seed)
        sess = ev["session"]
        for tau in taus:
            t0 = time.time()
            # tau is a traced scalar in the jitted evaluator: the whole
            # sweep reuses one compilation per split depth
            ad = sess.evaluate_adaptive(*ds.test, tau=float(tau),
                                        batch_size=256)
            rows.append({
                "table": "fig2_threshold", "dataset": dataset,
                "layer": layer, "tau_entropy": round(float(tau), 3),
                "tau_paper": round(float(H_CAP - tau), 3),
                "acc": round(float(np.mean(ad["acc"])), 4),
                "client_ratio": round(float(np.mean(ad["client_ratio"])), 4),
                "mean_entropy": round(float(np.mean(ad["mean_entropy"])), 4),
                "wall_s": round(time.time() - t0, 2),
            })
    return rows
