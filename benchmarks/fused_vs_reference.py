"""Engine throughput (rounds/sec) on the Averaging strategy: the
paper-faithful reference loop vs the scan+vmap fused engine vs the
mesh-sharded spmd engine — all behind ``repro.api.TrainSession`` on the
same N-client MLP split workload and identical data.

The reference engine pays two jit dispatches plus a ``float(loss)`` host
sync per client per minibatch; the fused engine runs the whole chunk as
one compiled scan; the spmd engine runs the same scan with the global
batch sharded over the mesh's ``data`` axis.  Emits:

  * ``BENCH_fused.json`` — the two-way comparison (schema validated by
    ``tests/test_bench_smoke.py``, unchanged);
  * ``BENCH_spmd.json``  — the three-way comparison.  The spmd leg records
    the session's ``engine_name`` selection note, and degrades to
    ``{"skipped": <reason>}`` when no multi-device mesh is available, so
    the manifest always records the real execution path.  Also carries the
    ``overlap`` leg: the staging pipeline (``data/staging.py``) on vs off
    over the engine's real pipelined chunk plan, with the measured
    stage-vs-compute ``overlap_fraction`` and the (required-zero) on/off
    trajectory delta — both behind the ``--max-delta`` gate;
  * ``BENCH_spmd_fsdp.json`` — the recipe-sharded leg: the ``--recipe``
    sharding recipe (tiny-leaf floor lowered so the MLP actually shards)
    on a ``(2, n/2, 1)`` lanes/data/model mesh — cohort lanes, params and
    Adam moments sharded, not replicated.  Real-or-skip-reason like the
    spmd leg, and gated by ``--max-delta`` when it ran.

  PYTHONPATH=src python -m benchmarks.fused_vs_reference
  PYTHONPATH=src python -m benchmarks.fused_vs_reference --spmd-devices 4 \
      --recipe greedy
"""
from __future__ import annotations

# must precede the first jax import: fake CPU devices for the spmd leg
from repro.launch.hostdevices import force_host_devices

force_host_devices("--spmd-devices")

import argparse
import dataclasses
import json
import time
from typing import Dict, List, Sequence

import numpy as np

from repro.api import TrainSession
from repro.config import HeteroProfile, OptimizerConfig, SplitEEConfig
from repro.core.splitee import MLPSplitModel
from repro.data.pipeline import ClientPartitioner
from repro.launch.mesh import make_lane_host_mesh
from repro.launch.shardings import NAMED_RECIPES, resolve_recipe

SCHEMA_KEYS = ("benchmark", "config", "reference", "fused", "speedup",
               "max_metric_delta")
SPMD_SCHEMA_KEYS = ("benchmark", "config", "reference", "fused", "spmd",
                    "speedup", "max_metric_delta", "overlap")
FSDP_SCHEMA_KEYS = ("benchmark", "config", "reference", "fused",
                    "spmd_fsdp", "speedup", "max_metric_delta")


def _make_session(engine: str, splits: Sequence[int], parts, *,
                  batch_size: int, total_steps: int, mesh=None,
                  recipe=None) -> TrainSession:
    model = MLPSplitModel(in_dim=32, hidden=64, num_classes=5, num_layers=4,
                          seed=0)
    return TrainSession.from_config(
        model,
        SplitEEConfig(profile=HeteroProfile(tuple(splits)),
                      strategy="averaging"),
        OptimizerConfig(lr=3e-3, total_steps=total_steps),
        parts, batch_size=batch_size, engine=engine, mesh=mesh,
        recipe=recipe)


def _metric_delta(ref: TrainSession, other: TrainSession) -> float:
    return float(max(
        max(abs(a.client_loss - b.client_loss),
            abs(a.server_loss - b.server_loss))
        for a, b in zip(ref.history, other.history)))


def run(rounds: int = 60, clients: int = 4, batch_size: int = 64,
        local_epochs: int = 1, out: str = "BENCH_fused.json",
        spmd_out: str = "BENCH_spmd.json", recipe: str = "greedy",
        fsdp_out: str = "BENCH_spmd_fsdp.json") -> List[Dict]:
    """Time every engine over ``rounds`` post-warmup rounds and write both
    comparison JSONs.  Returns benchmark rows for benchmarks/run.py."""
    if rounds < 1 or clients < 1:
        raise ValueError(f"need rounds >= 1 and clients >= 1, "
                         f"got rounds={rounds} clients={clients}")
    splits = [1 + (i % 3) for i in range(clients)]         # hetero cuts 1/2/3
    rng = np.random.default_rng(0)
    classes, d = 5, 32
    centers = rng.normal(size=(classes, d)) * 2.0
    y = rng.integers(0, classes, 4096).astype(np.int32)
    x = (centers[y] + rng.normal(size=(4096, d))).astype(np.float32)
    parts = ClientPartitioner(clients, seed=0).split(x, y)
    total_steps = 4 * rounds * local_epochs + 16

    def time_engine(sess, **run_kw):
        sess.train(rounds, local_epochs, **run_kw)         # warmup + compile
        t0 = time.perf_counter()
        sess.train(rounds, local_epochs, **run_kw)
        wall = time.perf_counter() - t0
        return sess, wall

    def make(engine):
        return _make_session(engine, splits, parts, batch_size=batch_size,
                             total_steps=total_steps)

    ref_tr, ref_wall = time_engine(make("reference"))
    fus_tr, fus_wall = time_engine(make("fused"), chunk_rounds=rounds)
    # only construction may skip the leg (supports() rejections: no mesh /
    # one device); a ValueError raised while *training* must propagate.
    # chunk_rounds stays 0 (auto): the run executes as the engine's real
    # pipelined chunk plan, staging overlapped with compute
    try:
        spmd_sess = make("spmd")
    except ValueError as e:
        spmd_tr, spmd_wall, spmd_skip = None, None, str(e)
    else:
        spmd_tr, spmd_wall = time_engine(spmd_sess)
        spmd_skip = None

    # engines consumed identical data: timed-window metrics must agree
    result = {
        "benchmark": "fused_vs_reference",
        "config": {"clients": clients, "splits": splits, "rounds": rounds,
                   "local_epochs": local_epochs, "batch_size": batch_size,
                   "strategy": "averaging", "model": "mlp-4x64"},
        "reference": {"wall_s": ref_wall,
                      "rounds_per_sec": rounds / ref_wall,
                      "engine_path": ref_tr.engine_name},
        "fused": {"wall_s": fus_wall, "rounds_per_sec": rounds / fus_wall,
                  "engine_path": fus_tr.engine_name},
        "speedup": ref_wall / fus_wall,
        "max_metric_delta": _metric_delta(ref_tr, fus_tr),
    }
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=1)

    import jax

    def leg_manifest(benchmark, leg, tr, wall, skip, config_extra):
        """A sharded leg's manifest: the base comparison plus the leg keyed
        ``leg`` — real (timings + engine_path) or ``{"skipped": reason}`` —
        with per-leg speedup/max_metric_delta dicts.  Keeps every
        ``BENCH_spmd*.json`` structurally in lockstep."""
        r = dict(result)
        r["benchmark"] = benchmark
        r["config"] = dict(result["config"], devices=len(jax.devices()),
                           **config_extra)
        r["speedup"] = {"fused": ref_wall / fus_wall,
                        leg: None if tr is None else ref_wall / wall}
        r["max_metric_delta"] = {
            "fused": _metric_delta(ref_tr, fus_tr),
            leg: None if tr is None else _metric_delta(ref_tr, tr)}
        r[leg] = ({"skipped": skip} if tr is None else
                  {"wall_s": wall, "rounds_per_sec": rounds / wall,
                   "engine_path": tr.engine_name})
        return r

    spmd_result = leg_manifest("spmd_vs_fused_vs_reference", "spmd",
                               spmd_tr, spmd_wall, spmd_skip, {})
    if spmd_tr is not None:
        spmd_result["spmd"]["stage_stats"] = dict(
            spmd_tr.engine.last_stage_stats)

    # ---- overlap on/off: the staging pipeline's contribution -----------
    # same engine, same pipelined chunk plan, double buffer on vs off;
    # trajectories must be bit-identical (the pipeline only reorders host
    # work), and the on leg must actually hide staging behind compute
    ov_engine = "spmd" if spmd_tr is not None else "fused"

    def time_overlap(on: bool):
        sess = make(ov_engine)
        sess.engine.overlap_staging = on
        sess, wall = time_engine(sess)
        return sess, wall, dict(sess.engine.last_stage_stats)

    on_tr, on_wall, on_stats = time_overlap(True)
    off_tr, off_wall, off_stats = time_overlap(False)
    spmd_result["overlap"] = {
        "engine": ov_engine,
        "on": {"wall_s": on_wall, "rounds_per_sec": rounds / on_wall,
               **on_stats},
        "off": {"wall_s": off_wall, "rounds_per_sec": rounds / off_wall,
                **off_stats},
        "speedup": off_wall / on_wall,
        "on_off_metric_delta": _metric_delta(on_tr, off_tr),
        "max_metric_delta_vs_reference": max(
            _metric_delta(ref_tr, on_tr), _metric_delta(ref_tr, off_tr)),
    }
    spmd_result["max_metric_delta"]["overlap"] = (
        spmd_result["overlap"]["max_metric_delta_vs_reference"])
    if spmd_out:
        with open(spmd_out, "w") as f:
            json.dump(spmd_result, f, indent=1)

    # ---- the recipe-sharded leg: lanes + FSDP on a (2, n/2, 1) mesh ----
    n_dev = len(jax.devices())
    fsdp_tr = fsdp_wall = fsdp_skip = None
    mesh_desc = None                   # recorded only when the leg ran
    if n_dev >= 4 and n_dev % 2 == 0:
        mesh = make_lane_host_mesh(2)
        mesh_desc = f"(2,{n_dev // 2},1) lanes/data/model"
        # the bench MLP's leaves are tiny; lower the replicate floor so the
        # leg measures *sharded* params/moments, not a de-facto replicate
        rec = dataclasses.replace(resolve_recipe(recipe),
                                  min_shard_elems=1 << 10)
        try:
            sess = _make_session("spmd", splits, parts,
                                 batch_size=batch_size,
                                 total_steps=total_steps, mesh=mesh,
                                 recipe=rec)
        except ValueError as e:
            fsdp_skip = str(e)
        else:
            fsdp_tr, fsdp_wall = time_engine(sess, chunk_rounds=rounds)
    else:
        fsdp_skip = (f"{n_dev} visible device(s); the lanes mesh needs an "
                     f"even count >= 4 (--spmd-devices 4)")

    fsdp_result = leg_manifest("spmd_fsdp_vs_fused_vs_reference",
                               "spmd_fsdp", fsdp_tr, fsdp_wall, fsdp_skip,
                               {"recipe": recipe, "mesh": mesh_desc})
    if fsdp_out:
        with open(fsdp_out, "w") as f:
            json.dump(fsdp_result, f, indent=1)

    rows = [{"name": f"fused_vs_reference/{eng}/N{clients}",
             "us_per_call": result[eng]["wall_s"] / rounds * 1e6,
             "derived": f"{result[eng]['rounds_per_sec']:.1f} rounds/s",
             **result} for eng in ("reference", "fused")]
    rows[0]["overlap"] = spmd_result["overlap"]
    if spmd_tr is not None:
        rows.append({"name": f"fused_vs_reference/spmd/N{clients}",
                     "us_per_call": spmd_wall / rounds * 1e6,
                     "derived": f"{rounds / spmd_wall:.1f} rounds/s",
                     **spmd_result})
    if fsdp_tr is not None:
        rows.append({"name": f"fused_vs_reference/spmd_fsdp/N{clients}",
                     "us_per_call": fsdp_wall / rounds * 1e6,
                     "derived": f"{rounds / fsdp_wall:.1f} rounds/s",
                     **fsdp_result})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-epochs", type=int, default=1)
    ap.add_argument("--out", default="BENCH_fused.json")
    ap.add_argument("--spmd-out", default="BENCH_spmd.json")
    ap.add_argument("--fsdp-out", default="BENCH_spmd_fsdp.json")
    ap.add_argument("--recipe", default="greedy",
                    choices=sorted(NAMED_RECIPES),
                    help="sharding recipe for the lanes+FSDP leg "
                         "(launch/shardings.py)")
    ap.add_argument("--spmd-devices", type=int, default=0,
                    help="force N fake CPU devices so the spmd leg runs on "
                         "a single-device host (consumed pre-import)")
    ap.add_argument("--max-delta", type=float, default=0.0,
                    help="exit non-zero when any engine's metric delta vs "
                         "the reference exceeds this bound (the CI "
                         "bench-smoke gate; 0 disables)")
    args = ap.parse_args()
    rows = run(rounds=args.rounds, clients=args.clients,
               local_epochs=args.local_epochs, out=args.out,
               spmd_out=args.spmd_out, recipe=args.recipe,
               fsdp_out=args.fsdp_out)
    by_leg = {r["name"].split("/")[1]: r for r in rows}
    r = rows[0]
    print(f"reference: {r['reference']['rounds_per_sec']:.1f} rounds/s")
    print(f"fused    : {r['fused']['rounds_per_sec']:.1f} rounds/s")
    print(f"speedup  : {r['speedup']:.1f}x   "
          f"(max metric delta {r['max_metric_delta']:.2e})  -> {args.out}")
    s = by_leg.get("spmd")
    if s is not None:
        print(f"spmd     : {s['spmd']['rounds_per_sec']:.1f} rounds/s "
              f"on {s['config']['devices']} devices "
              f"(delta vs reference "
              f"{s['max_metric_delta']['spmd']:.2e})  -> {args.spmd_out}")
    else:
        print(f"spmd     : skipped -> {args.spmd_out}")
    ov = next((r["overlap"] for r in rows if "overlap" in r), None)
    if ov is not None:
        print(f"overlap  : {ov['engine']} staging pipeline on "
              f"{ov['on']['rounds_per_sec']:.1f} vs off "
              f"{ov['off']['rounds_per_sec']:.1f} rounds/s "
              f"({ov['speedup']:.2f}x, overlap fraction "
              f"{ov['on']['overlap_fraction']:.2f}, on/off delta "
              f"{ov['on_off_metric_delta']:.1e})")
    fs = by_leg.get("spmd_fsdp")
    if fs is not None:
        print(f"spmd_fsdp: {fs['spmd_fsdp']['rounds_per_sec']:.1f} rounds/s "
              f"(recipe {args.recipe}, lanes mesh, delta vs reference "
              f"{fs['max_metric_delta']['spmd_fsdp']:.2e})  "
              f"-> {args.fsdp_out}")
    else:
        print(f"spmd_fsdp: skipped -> {args.fsdp_out}")

    if args.max_delta > 0:
        deltas = {"fused": r["max_metric_delta"]}
        if s is not None:
            deltas["spmd"] = s["max_metric_delta"]["spmd"]
        if fs is not None:
            deltas["spmd_fsdp"] = fs["max_metric_delta"]["spmd_fsdp"]
        if ov is not None:
            deltas["overlap"] = max(ov["max_metric_delta_vs_reference"],
                                    ov["on_off_metric_delta"])
        over = {k: v for k, v in deltas.items() if v > args.max_delta}
        if over:
            import sys
            print(f"FAIL: metric delta vs reference exceeds "
                  f"--max-delta {args.max_delta:g}: "
                  + ", ".join(f"{k}={v:.3e}" for k, v in over.items()))
            sys.exit(1)
        print(f"delta gate ok (<= {args.max_delta:g}): "
              + ", ".join(f"{k}={v:.3e}" for k, v in deltas.items()))


if __name__ == "__main__":
    main()
