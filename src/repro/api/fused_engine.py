"""Fused engine: scan + vmap whole-chunk execution as a pure
``TrainState -> TrainState`` executor (see docs/ENGINES.md).

  * **Cohorts + vmap** — clients sharing a split layer have identical pytree
    structure, so each cohort is stacked along a leading lane axis and its
    combined client+server step runs under one ``jax.vmap``.
  * **Rounds under lax.scan** — the exact minibatch sequence the reference
    engine would draw is pre-staged as ``[rounds, k, E, B, ...]`` device
    tensors and the whole chunk rolls through one ``jax.lax.scan`` with
    donated carry; losses come back as stacked per-round arrays (one host
    sync per chunk).
  * **In-graph Eq. (1)** — ``stacked_cross_layer_aggregate`` under a
    ``lax.cond`` on the traced ``(t+1) % aggregate_every == 0`` predicate.

Numerically equivalent to the reference engine in ``eq1`` grad mode (both
compose the same client/server step math through
``core.spmd.make_cohort_train_step``); enforced by
``tests/test_fused_engine.py`` and ``tests/test_session.py``.  The
Sequential strategy (Alg. 1) is inherently ordered across clients and is
not supported — ``resolve_engine("auto", ...)`` falls back to the
reference engine for it.

``repro.api.spmd_engine.SpmdEngine`` subclasses this engine and overrides
the :meth:`FusedEngine._compile_chunk` (jit with mesh shardings),
:meth:`FusedEngine._put_batch` / :meth:`FusedEngine._put_ts` (host
staging -> sharded, possibly process-global, device placement),
:meth:`FusedEngine._stack_carry` (recipe-sharded carry) and
:meth:`FusedEngine._fetch_carry` / :meth:`FusedEngine._host_losses`
(multi-host readback) hooks to stage the identical round body with mesh
shardings — including the overlapped staging pipeline, which calls the
same hooks from its producer thread.
"""
from __future__ import annotations

import itertools
import os
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.engines import (Engine, SessionContext, cohort_layout,
                               ragged_cohort_reason, register_engine)
from repro.api.state import TrainState
from repro.core.aggregation import stacked_cross_layer_aggregate
from repro.core.splitee import stack_pytrees, unstack_pytrees
from repro.core.spmd import make_cohort_train_step
from repro.core.strategies import RoundMetrics
from repro.data.pipeline import effective_batch_size, prestage_batches
from repro.data.staging import StagedChunkPipeline


@register_engine("fused")
class FusedEngine(Engine):

    #: staging budget (bytes) for the auto ``chunk_rounds`` default: when a
    #: run's whole pre-staged ``[rounds, k, E, B, ...]`` tensor would exceed
    #: it, the run is split into budget-sized chunks instead of silently
    #: staging everything (full-size configs OOM before the first step
    #: otherwise).  The budget bounds *resident* staged data: under the
    #: overlapped pipeline it is divided by ``pipeline_depth`` so the
    #: staged-ahead chunks together still fit.  Override per instance, or
    #: via REPRO_STAGE_BUDGET_MB; must be strictly positive either way.
    stage_budget_bytes: int = 1 << 30

    #: overlapped staging: stage chunk n+1 on a background thread (a
    #: depth-2 double buffer, ``data.staging.StagedChunkPipeline``) while
    #: the jitted scan for chunk n runs, and fetch chunk n's losses only
    #: after chunk n+1 is dispatched.  Bit-identical trajectory either way
    #: (tests/test_staging.py); REPRO_OVERLAP_STAGING=0 is the kill switch.
    overlap_staging: bool = True

    #: staged chunks resident at once under the pipeline (2 = double
    #: buffer: one in compute, one staged ahead)
    pipeline_depth: int = 2

    #: with overlapped staging, a budget-sized single-chunk plan is
    #: subdivided into up to this many chunks so the double buffer has
    #: work to overlap (an explicit ``chunk_rounds`` is never subdivided;
    #: chunking is trajectory-neutral, see docs/ENGINES.md)
    pipeline_min_chunks: int = 4

    def __init__(self, ctx: SessionContext):
        super().__init__(ctx)
        self._cohort_lis, self._lanes = cohort_layout(
            ctx.profile.split_layers)
        self._counts: Dict[int, int] = {li: len(v)
                                        for li, v in self._lanes.items()}
        #: client index -> (cohort cut layer, lane position in the cohort)
        self._lane_pos: Dict[int, Tuple[int, int]] = {
            i: (li, j) for li in self._cohort_lis
            for j, i in enumerate(self._lanes[li])}
        self._chunk_fns: Dict[int, Callable] = {}
        #: staging/overlap accounting for the most recent :meth:`run`
        #: (``data.staging.StageStats.as_dict`` — the bench's overlap leg
        #: reads it)
        self.last_stage_stats: Dict = {}

    @classmethod
    def supports(cls, ctx: SessionContext):
        if ctx.strategy not in ("averaging", "distributed"):
            return (f"supports averaging/distributed only, not "
                    f"{ctx.strategy!r} (the Sequential strategy is ordered "
                    f"across clients — use the reference engine)")
        return ragged_cohort_reason(ctx)

    # -------------------------------------------------------------- tracing
    def _vstep(self, li: int) -> Callable:
        """One cohort step: the shared ``core.spmd.make_cohort_train_step``
        (eq1: exactly the reference engine's round body; sum: one fused
        backward of the summed loss), vmapped over lanes."""
        combined = make_cohort_train_step(self.ctx.model, self.ctx.opt_cfg,
                                          li, self.ctx.grad_mode)
        return jax.vmap(combined, in_axes=(0, 0, 0, 0, 0, 0, None, None))

    def _compile_chunk(self, chunk: Callable) -> Callable:
        """Stage the traced chunk.  The spmd subclass overrides this with
        mesh in/out shardings; here it is a plain donated jit."""
        return jax.jit(chunk, donate_argnums=(0,))

    def _chunk_fn(self, local_epochs: int) -> Callable:
        """Jitted ``(carry, ts, xs, ys) -> (carry, (closs[n], sloss[n]))``
        scanning the round body over a chunk; carry buffers are donated."""
        if local_epochs in self._chunk_fns:
            return self._chunk_fns[local_epochs]

        ctx = self.ctx
        cohort_lis = self._cohort_lis
        counts = self._counts
        vsteps = {li: self._vstep(li) for li in cohort_lis}
        denom = float(ctx.N * local_epochs)
        averaging = ctx.strategy == "averaging"
        agg_every = ctx.cfg.aggregate_every
        schedule, lr_div = ctx.schedule, ctx.server_lr_div

        def epoch_body(carry, bx, by, lr, lr_s):
            out, csum, ssum = {}, 0.0, 0.0
            for li in cohort_lis:
                client, copt, server, sopt = carry[li]
                client, copt, server, sopt, closs, sloss = vsteps[li](
                    client, copt, server, sopt, bx[li], by[li], lr, lr_s)
                out[li] = (client, copt, server, sopt)
                csum = csum + jnp.sum(closs)
                ssum = ssum + jnp.sum(sloss)
            return out, (csum, ssum)

        def round_body(carry, inp):
            t, xs, ys = inp
            lr = schedule(t)
            lr_s = lr / lr_div

            def body(c, data):
                return epoch_body(c, data[0], data[1], lr, lr_s)

            carry, (cs, ss) = jax.lax.scan(body, carry, (xs, ys))
            if averaging:
                def aggregated(c):
                    tr = stacked_cross_layer_aggregate(
                        {li: c[li][2]["trainable"] for li in cohort_lis},
                        counts)
                    st = stacked_cross_layer_aggregate(
                        {li: c[li][2]["state"] for li in cohort_lis},
                        counts)
                    return {li: (c[li][0], c[li][1],
                                 {"trainable": tr[li], "state": st[li]},
                                 c[li][3])
                            for li in cohort_lis}

                # cond (not where) so non-boundary rounds skip the Eq. (1)
                # means entirely — still in-graph, still no host sync
                do = ((t + 1) % agg_every) == 0
                carry = jax.lax.cond(do, aggregated, lambda c: c, carry)
            return carry, (jnp.sum(cs) / denom, jnp.sum(ss) / denom)

        def chunk(carry, ts, xs, ys):
            return jax.lax.scan(round_body, carry, (ts, xs, ys))

        fn = self._compile_chunk(chunk)
        self._chunk_fns[local_epochs] = fn
        return fn

    # ------------------------------------------------------------- staging
    def _put_batch(self, arr: np.ndarray, li: int) -> jnp.ndarray:
        """Host-staged batch for cohort ``li`` -> device.  The spmd subclass
        overrides this to place each device's slice directly into the
        cohort's batch sharding."""
        return jnp.asarray(arr)

    def _stage_chunk(self, rounds: int, local_epochs: int):
        """Draw the chunk's minibatches through the session's data cursor
        (the same per-client sequence the reference engine would consume,
        in client-index order) straight into preallocated
        ``{li: [rounds, k, E, B, ...]}`` cohort buffers — one host copy
        per batch, no list/``np.stack``/lane-stack intermediates — then
        hand each buffer to :meth:`_put_batch`."""
        def drawn(i):
            while True:
                yield self.ctx.data.draw(i)

        bufs: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        for i in range(self.ctx.N):
            li, j = self._lane_pos[i]
            it = drawn(i)
            first = next(it)          # fixes the staged shapes/dtypes
            if li not in bufs:
                x0, y0 = first
                k = self._counts[li]
                bufs[li] = (
                    np.empty((rounds, local_epochs, k, *x0.shape), x0.dtype),
                    np.empty((rounds, local_epochs, k, *y0.shape), y0.dtype))
            bx, by = bufs[li]
            prestage_batches(itertools.chain([first], it), rounds,
                             local_epochs, out=(bx[:, :, j], by[:, :, j]))
        xs = {li: self._put_batch(bufs[li][0], li)
              for li in self._cohort_lis}
        ys = {li: self._put_batch(bufs[li][1], li)
              for li in self._cohort_lis}
        return xs, ys

    def _round_stage_bytes(self, local_epochs: int) -> int:
        """Host bytes one round of pre-staged batches occupies (every
        client's ``local_epochs`` minibatches, x and y)."""
        total = 0
        for x, y in self.ctx.client_data:
            eb = effective_batch_size(len(x), self.ctx.batch_size)
            per_example = (x.dtype.itemsize * int(np.prod(x.shape[1:]))
                           + y.dtype.itemsize * int(np.prod(y.shape[1:])))
            total += local_epochs * eb * per_example
        return total

    def _auto_chunk_rounds(self, rounds: int, local_epochs: int,
                           overlap: bool = False) -> int:
        """The default chunk size when the caller passed ``chunk_rounds=0``:
        as many rounds as fit the staging budget (at least one).  With
        ``overlap`` the pipeline keeps up to ``pipeline_depth`` staged
        chunks resident at once (one in compute plus staged-ahead), so the
        budget is divided by the depth — resident staged data stays within
        ``stage_budget_bytes`` instead of depth times it.  An
        explicit per-instance ``stage_budget_bytes`` wins over the
        REPRO_STAGE_BUDGET_MB environment default.  Either knob must be
        strictly positive — a zero/negative budget used to silently
        degrade to ``chunk_rounds=1``, hiding the misconfiguration."""
        budget = self.stage_budget_bytes
        env = os.environ.get("REPRO_STAGE_BUDGET_MB")
        if env and budget == FusedEngine.stage_budget_bytes:
            try:
                budget = int(env) << 20
            except ValueError:
                raise ValueError(
                    f"REPRO_STAGE_BUDGET_MB={env!r} is not an integer "
                    f"megabyte count") from None
            if budget <= 0:
                raise ValueError(
                    f"REPRO_STAGE_BUDGET_MB={env} must be strictly "
                    f"positive: a 0/negative staging budget cannot hold "
                    f"even one round of pre-staged batches")
        if budget <= 0:
            raise ValueError(
                f"stage_budget_bytes={budget} must be strictly positive: "
                f"a 0/negative staging budget cannot hold even one round "
                f"of pre-staged batches (set FusedEngine.stage_budget_bytes "
                f"or REPRO_STAGE_BUDGET_MB to a real byte/MB count)")
        if overlap:
            budget //= self.pipeline_depth
        per_round = max(1, self._round_stage_bytes(local_epochs))
        return max(1, min(rounds, budget // per_round))

    def _overlap_enabled(self) -> bool:
        """The ``overlap_staging`` knob, with REPRO_OVERLAP_STAGING (0 /
        false / off disables, anything else enables) taking precedence."""
        env = os.environ.get("REPRO_OVERLAP_STAGING")
        if env is not None:
            return env.strip().lower() not in ("0", "false", "off", "no")
        return self.overlap_staging

    def _chunk_plan(self, rounds: int, chunk_rounds: int,
                    local_epochs: int, overlap: bool) -> List[int]:
        """The run's chunk sizes in execution order.  An explicit
        ``chunk_rounds`` is honored exactly; the auto default is the
        staging-budget chunk (budget divided by ``pipeline_depth`` under
        overlap), subdivided (equal-ish, for compile-cache
        reuse) into up to ``pipeline_min_chunks`` pieces when overlap is
        on and the budget would cover the run in one chunk — a pipeline
        with a single chunk has nothing to overlap.  Chunk boundaries
        never change the trajectory (docs/ENGINES.md, tested)."""
        chunk = (chunk_rounds if chunk_rounds > 0
                 else self._auto_chunk_rounds(rounds, local_epochs, overlap))
        if (chunk_rounds <= 0 and overlap and chunk >= rounds
                and rounds >= 2):
            pieces = min(self.pipeline_min_chunks, rounds)
            chunk = -(-rounds // pieces)                   # ceil
        plan = []
        done = 0
        while done < rounds:
            n = min(chunk, rounds - done)
            plan.append(n)
            done += n
        return plan

    def _stack_carry(self, clients, copts, servers, sopts):
        model = self.ctx.model
        carry = {}
        for li in self._cohort_lis:
            lanes = self._lanes[li]
            carry[li] = (
                model.stack_clients([clients[i] for i in lanes]),
                stack_pytrees([copts[i] for i in lanes]),
                model.stack_clients([servers[i] for i in lanes]),
                stack_pytrees([sopts[i] for i in lanes]),
            )
        return carry

    def _unstack_carry(self, carry, clients, copts, servers, sopts):
        for li in self._cohort_lis:
            lanes = self._lanes[li]
            cs, co, ss, so = (unstack_pytrees(t, len(lanes))
                              for t in carry[li])
            for j, i in enumerate(lanes):
                clients[i], copts[i] = cs[j], co[j]
                servers[i], sopts[i] = ss[j], so[j]

    def _fetch_carry(self, carry):
        """Hook: the run's final device carry, host-readable.  Identity
        here (single-process arrays are always addressable); the spmd
        engine reshards to replicated + fetches when the carry spans
        processes."""
        return carry

    def _put_ts(self, t: int, n: int):
        """Hook: the chunk's round-index vector ``[t, t+n)`` as a device
        array.  The spmd engine overrides this to build a process-global
        replicated array under multi-host runs."""
        return jnp.arange(t, t + n, dtype=jnp.int32)

    def _host_losses(self, closs, sloss):
        """Hook: a chunk's stacked per-round losses as host arrays (the
        one blocking sync per chunk).  The spmd engine overrides this to
        read a local shard of the replicated outputs under multi-host."""
        return np.asarray(closs), np.asarray(sloss)

    def _chunk_metrics(self, t0: int, n: int, closs, sloss,
                       log_every: int) -> List[RoundMetrics]:
        closs, sloss = self._host_losses(closs, sloss)       # one sync/chunk
        metrics = []
        for r in range(n):
            m = RoundMetrics(t0 + r, float(closs[r]), float(sloss[r]))
            metrics.append(m)
            if log_every and (m.round % log_every == 0):
                print(f"round {m.round:4d}  client_loss {m.client_loss:.4f}"
                      f"  server_loss {m.server_loss:.4f}")
        return metrics

    # ------------------------------------------------------------ training
    def run(self, state: TrainState, rounds: int, local_epochs: int = 1,
            log_every: int = 0, chunk_rounds: int = 0
            ) -> Tuple[TrainState, List[RoundMetrics]]:
        """``chunk_rounds`` bounds how many rounds of pre-staged data are
        resident at once (0 = auto: budget-sized chunks, subdivided for the
        staging pipeline — chunking never changes the trajectory, see
        docs/ENGINES.md).

        Chunks execute as a producer/consumer pipeline: the carry is
        stacked and placed once per run and stays device-resident across
        chunks; a background producer stages chunk n+1 (draw + fill +
        ``device_put``) while the jitted scan for chunk n runs, and the
        host sync on chunk n's losses happens only after chunk n+1 is
        dispatched — JAX dispatch is async, so the old per-chunk
        ``np.asarray`` used to serialize staging against compute."""
        if rounds <= 0:
            return state, []
        self.ctx.data.align(state.batches_drawn)
        overlap = self._overlap_enabled()
        plan = self._chunk_plan(rounds, chunk_rounds, local_epochs, overlap)
        fn = self._chunk_fn(local_epochs)
        clients, copts = list(state.clients), list(state.client_opts)
        servers, sopts = list(state.servers), list(state.server_opts)
        carry = self._stack_carry(clients, copts, servers, sopts)
        t0 = int(state.round)

        pipeline = StagedChunkPipeline(
            lambda n: self._stage_chunk(n, local_epochs), plan,
            depth=self.pipeline_depth, overlap=overlap)
        metrics: List[RoundMetrics] = []
        pending = None                  # (chunk start round, n, closs, sloss)
        try:
            t = t0
            for n in plan:
                xs, ys = pipeline.get()
                ts = self._put_ts(t, n)
                # async dispatch: this chunk's scan starts on device while
                # the producer stages the next chunk ...
                carry, (closs, sloss) = fn(carry, ts, xs, ys)
                # ... and only then does the host block on the PREVIOUS
                # chunk's losses (syncing this chunk's would serialize the
                # whole loop again)
                if pending is not None:
                    metrics.extend(self._chunk_metrics(*pending, log_every))
                    pipeline.release()
                pending = (t, n, closs, sloss)
                t += n
            metrics.extend(self._chunk_metrics(*pending, log_every))
            pipeline.release()
        finally:
            pipeline.close()
            self.last_stage_stats = pipeline.stats.as_dict()

        self._unstack_carry(self._fetch_carry(carry), clients, copts,
                            servers, sopts)
        new_state = state.replace(
            clients=tuple(clients), client_opts=tuple(copts),
            servers=tuple(servers), server_opts=tuple(sopts),
            round=jnp.asarray(t0 + rounds, jnp.int32),
            batches_drawn=state.batches_drawn
            + jnp.asarray(rounds * local_epochs, jnp.int32))
        return new_state, metrics
