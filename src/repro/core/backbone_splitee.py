"""``BackboneSplitModel`` — the production backbones behind the
``SplitModel`` protocol.

The ``configs/`` zoo (GLM-4, DeepSeek-V3, Qwen3-MoE, RWKV6, Whisper, …)
describes deep decoder backbones that, until this adapter, could only run
the monolithic fused-SPMD step (core/spmd.py).  This module partitions an
``init_backbone`` parameter tree into the paper's split-learning shape so
any registered engine (``reference``/``fused``/``spmd``) trains them
through :class:`repro.api.TrainSession`:

  * cut layers are the config's ``exit_layers`` — the segment boundaries of
    ``build_plan`` — so a client with cut layer ``l_i = exit_layers[b]``
    holds segments ``0..b`` (layers 1..l_i) plus exit head ``b`` (the
    paper's client output layer), and its server holds segments ``b+1..``
    plus the LM head;
  * server trainables are keyed ``seg{si}``/``head``: segment granularity
    *is* layer granularity at the cut points, so Eq. (1) cross-layer
    aggregation matches common trunks by key exactly as the ``layer{l}``
    keying does for the ResNet/MLP adapters;
  * clients sharing a cut layer have identical pytree structure and
    identical seed-derived values (paper §III-B), so cohorts stack along a
    lane axis (``_StackMixin``) and the fused/spmd engines vmap them
    unchanged.

The task is sequence classification over the synthetic token pipeline
(``data.synthetic.SyntheticSeqClsDataset``): ``x`` is ``(B, T)`` int32
tokens, labels are class ids below the vocab size, and both the exit head
and the LM head are scored at the last position, giving ``(B, V)`` logits —
the same ``(h, logits)`` contract the engines and the ``SplitEvaluator``
already consume.

Scope notes:

  * audio configs (Whisper) cross-attend over the stubbed encoder states —
    the adapter feeds the documented zeros stub through each side's own
    frontend projector; VLM configs train token-only (the vision frontend
    stays out of the trainables);
  * Zamba2's globally-shared attention block is duplicated per side: the
    client family and the server family each train their own copy (they
    start identical; the server copies are Eq.(1)-aggregated like any
    shared key).  This is the split-learning analogue of the 1/N
    participation approximation core/spmd.py documents;
  * MoE router load-balance aux losses ride the optional
    ``client_loss`` / ``server_loss`` hooks (``core.strategies``): each
    family's training loss adds the aux total of its *own* segments
    (weighted by the config's ``router_aux_weight``, applied inside
    ``models.moe.route``), so routers on both sides of the cut stay
    load-balanced while evaluation logits remain aux-free.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core.losses import softmax_cross_entropy
from repro.core.splitee import _StackMixin
from repro.models import frontend as frontend_mod
from repro.models import heads as heads_mod
from repro.models.backbone import _run_forward, build_plan, init_backbone
from repro.models.common import embed


@dataclass
class BackboneSplitModel(_StackMixin):
    """Split a ``configs/`` backbone at any of its ``exit_layers``."""

    cfg: ModelConfig
    seed: int = 0

    def __post_init__(self):
        if not self.cfg.exit_layers:
            raise ValueError(
                f"{self.cfg.name}: BackboneSplitModel needs exit_layers — "
                f"cut layers must sit at exit-head boundaries")
        self.plan = build_plan(self.cfg)
        self.full_params = init_backbone(jax.random.PRNGKey(self.seed),
                                         self.cfg)
        self._exits = tuple(sorted(self.cfg.exit_layers))
        self._boundary = {li: b for b, li in enumerate(self._exits)}

    # -------------------------------------------------------------- identity
    @property
    def name(self) -> str:
        """Recorded in checkpoint manifests for resume validation."""
        return self.cfg.name

    @property
    def num_layers(self) -> int:
        return self.cfg.num_layers

    @property
    def cut_layers(self) -> Tuple[int, ...]:
        """The valid cut layers (= sorted exit layers)."""
        return self._exits

    def _boundary_of(self, li: int) -> int:
        try:
            return self._boundary[li]
        except KeyError:
            raise ValueError(
                f"{self.cfg.name}: cut layer {li} is not an exit boundary; "
                f"valid cut layers are {self._exits}") from None

    # ------------------------------------------------------------ partitions
    def _side_extras(self) -> Dict[str, Any]:
        """Params both sides need a copy of: the shared attention block
        (Zamba2) and, for cross-attending archs, the enc projector."""
        extras: Dict[str, Any] = {}
        if "shared_attn" in self.full_params:
            extras["shared_attn"] = self.full_params["shared_attn"]
        if self.cfg.cross_attention and "frontend" in self.full_params:
            extras["frontend"] = self.full_params["frontend"]
        return extras

    def make_client(self, li: int) -> Dict[str, Any]:
        b = self._boundary_of(li)
        trainable: Dict[str, Any] = {
            "embed": self.full_params["embed"],
            "segments": [self.full_params["segments"][si]
                         for si in range(b + 1)],
            "out": self.full_params["exit_heads"][b],
        }
        trainable.update(self._side_extras())
        return {"trainable": trainable, "state": {}}

    def make_server(self, li: int) -> Dict[str, Any]:
        b = self._boundary_of(li)
        trainable: Dict[str, Any] = {
            f"seg{si}": self.full_params["segments"][si]
            for si in range(b + 1, len(self.plan))
        }
        trainable["head"] = self.full_params["head"]
        trainable.update(self._side_extras())
        return {"trainable": trainable, "state": {}}

    # --------------------------------------------------------------- forward
    def _enc_for(self, trainable: Dict[str, Any], B: int):
        """The stubbed, projected encoder states for cross-attention archs
        (zeros — the documented frontend carve-out), else None."""
        if not self.cfg.cross_attention:
            return None
        raw = jnp.zeros((B, self.cfg.cross_source_len,
                         frontend_mod.WHISPER_FRAME_DIM), self.cfg.dtype)
        return frontend_mod.project(trainable["frontend"], raw).astype(
            self.cfg.dtype)

    def _apply_segment(self, seg_params, si: int, x, positions, enc,
                       shared_p):
        """Run one segment; returns ``(x, aux)`` where ``aux`` totals the
        segment's MoE load-balance losses (0.0 for dense segments)."""
        aux = jnp.zeros((), jnp.float32)
        for ri, run in enumerate(self.plan[si]):
            x, _, a = _run_forward(run, seg_params[ri], shared_p, x,
                                   positions, self.cfg, None, None, enc,
                                   False)
            aux = aux + a
        return x, aux

    def _client_run(self, trainable, x):
        """(h, last-position exit logits, aux total over client segments)."""
        h = embed(trainable["embed"], x).astype(self.cfg.dtype)
        positions = jnp.arange(h.shape[1], dtype=jnp.int32)
        enc = self._enc_for(trainable, h.shape[0])
        shared_p = trainable.get("shared_attn")
        aux = jnp.zeros((), jnp.float32)
        for si in range(len(trainable["segments"])):
            h, a = self._apply_segment(trainable["segments"][si], si, h,
                                       positions, enc, shared_p)
            aux = aux + a
        logits = heads_mod.exit_head(trainable["out"], h, self.cfg)
        return h, logits[:, -1, :], aux

    def _server_run(self, trainable, h, li: int):
        """(last-position head logits, aux total over server segments)."""
        b = self._boundary_of(li)
        positions = jnp.arange(h.shape[1], dtype=jnp.int32)
        enc = self._enc_for(trainable, h.shape[0])
        shared_p = trainable.get("shared_attn")
        h = h.astype(self.cfg.dtype)
        aux = jnp.zeros((), jnp.float32)
        for si in range(b + 1, len(self.plan)):
            h, a = self._apply_segment(trainable[f"seg{si}"], si, h,
                                       positions, enc, shared_p)
            aux = aux + a
        logits = heads_mod.lm_head(trainable["head"], h, self.cfg)
        return logits[:, -1, :], aux

    def client_forward(self, trainable, state, x, train: bool
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, Any]:
        h, logits, _ = self._client_run(trainable, x)
        return h, logits, state

    def server_forward(self, trainable, state, h, li: int, train: bool
                       ) -> Tuple[jnp.ndarray, Any]:
        logits, _ = self._server_run(trainable, h, li)
        return logits, state

    # ------------------------------------------------------- training losses
    def client_loss(self, trainable, state, x, y
                    ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, Any]]:
        """The ``core.strategies`` client-loss hook: exit-head CE plus the
        client segments' MoE load-balance aux total (config-weighted inside
        the router), so client-side routers train balanced."""
        h, logits, aux = self._client_run(trainable, x)
        return softmax_cross_entropy(logits, y) + aux, (h, state)

    def server_loss(self, trainable, state, h, li: int, y
                    ) -> Tuple[jnp.ndarray, Any]:
        """The server-loss hook: final-head CE plus the server segments'
        aux total (mirrors ``core.spmd.hetero_losses`` adding
        ``out.aux_loss`` to the monolithic server loss)."""
        logits, aux = self._server_run(trainable, h, li)
        return softmax_cross_entropy(logits, y) + aux, state
