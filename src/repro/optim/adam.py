"""Adam optimizer (paper Table II) as pure pytree transforms.

State dtype is configurable: fp32 (default) or bf16 for the 100B+ assigned
architectures where optimizer memory dominates the HBM budget (see
docs/EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig


@dataclass
class AdamState:
    step: jnp.ndarray       # int32 scalar
    m: Any                  # pytree like params
    v: Any


# keyed registration so checkpoint manifests name leaves ".step"/".m"/".v"
# instead of flattened indices (see checkpoint/checkpoint.py, docs/API.md)
jax.tree_util.register_pytree_with_keys(
    AdamState,
    lambda s: (((jax.tree_util.GetAttrKey("step"), s.step),
                (jax.tree_util.GetAttrKey("m"), s.m),
                (jax.tree_util.GetAttrKey("v"), s.v)), None),
    lambda _, c: AdamState(*c),
    flatten_func=lambda s: ((s.step, s.m, s.v), None),
)


def adam_init(params: Any, cfg: OptimizerConfig) -> AdamState:
    z = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return AdamState(step=jnp.zeros((), jnp.int32),
                     m=jax.tree.map(z, params), v=jax.tree.map(z, params))


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adam_update(params: Any, grads: Any, state: AdamState,
                cfg: OptimizerConfig, lr: jnp.ndarray,
                lr_scale_tree: Optional[Any] = None):
    """One Adam step.  ``lr_scale_tree`` (optional, same structure as params
    or a prefix) multiplies the per-leaf learning rate — used by the
    Sequential strategy's server-LR divisor and by per-layer SplitEE scaling.
    Returns (new_params, new_state)."""
    step = state.step + 1
    if cfg.grad_clip > 0:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, s=None):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        if cfg.weight_decay > 0:
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        eff_lr = lr if s is None else lr * s
        p_new = p.astype(jnp.float32) - eff_lr * update
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    if lr_scale_tree is None:
        out = jax.tree.map(upd, params, grads, state.m, state.v)
    else:
        out = jax.tree.map(upd, params, grads, state.m, state.v, lr_scale_tree)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, AdamState(step=step, m=new_m, v=new_v)
