"""The SPMD collective form of Eq. (1): ``masked_mean_over_axis`` under
``shard_map`` on a multi-device mesh equals the per-client loop oracle.
Runs in a subprocess so the 8-device XLA flag never leaks."""
import json
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.core.aggregation import masked_mean_over_axis

mesh = jax.make_mesh((8,), ("clients",))
rng = np.random.default_rng(0)

# 8 clients, layer participation mask (paper C_l): clients 3..7 hold layer l
values = jnp.array(rng.normal(size=(8, 4)), jnp.float32)
participate = jnp.array([0, 0, 0, 1, 1, 1, 1, 1], jnp.float32)

def agg(v, p):
    return masked_mean_over_axis(v, p[0], "clients")

out = shard_map(agg, mesh=mesh, in_specs=(P("clients"), P("clients")),
                out_specs=P("clients"))(values, participate[:, None])

members = np.nonzero(np.asarray(participate))[0]
mean = np.asarray(values)[members].mean(0)
res = {"ok_members": True, "ok_passthrough": True}
for i in range(8):
    got = np.asarray(out)[i]
    want = mean if participate[i] else np.asarray(values)[i]
    key = "ok_members" if participate[i] else "ok_passthrough"
    if not np.allclose(got, want, atol=1e-6):
        res[key] = False
print(json.dumps(res))
"""


def test_masked_mean_psum_matches_loop():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"},
                       cwd=".", timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok_members"], "members must receive the C_l mean"
    assert out["ok_passthrough"], "non-members keep their value"
