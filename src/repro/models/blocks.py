"""Block dispatch: one pre-norm residual block = mixer + FFN.

Mixer kinds : "attn" (GQA), "mla" (DeepSeek latent attention),
              "mamba2", "rwkv6".
FFN kinds   : "mlp" (SwiGLU/GeLU), "moe", "rwkv_cm", "none".

Blocks are pytree-uniform within a kind so that runs of identical blocks can
be stacked and driven by ``lax.scan`` in the backbone.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import init_rmsnorm, rmsnorm
from repro.models.mlp import init_mlp, mlp_forward

MIXER_INIT = {
    "attn": attn_mod.init_gqa,
    "mla": attn_mod.init_mla,
    "mamba2": ssm_mod.init_mamba2,
    "rwkv6": ssm_mod.init_rwkv6,
}


def init_block(rng, cfg: ModelConfig, mixer: str, ffn: str) -> dict:
    ks = jax.random.split(rng, 4)
    p: dict = {"norm1": init_rmsnorm(cfg.d_model, cfg.param_dtype),
               "mixer": MIXER_INIT[mixer](ks[0], cfg)}
    if ffn != "none":
        p["norm2"] = init_rmsnorm(cfg.d_model, cfg.param_dtype)
        if ffn == "mlp":
            p["ffn"] = init_mlp(ks[1], cfg)
        elif ffn == "moe":
            p["ffn"] = moe_mod.init_moe(ks[1], cfg)
        elif ffn == "rwkv_cm":
            p["ffn"] = ssm_mod.init_rwkv_cm(ks[1], cfg)
        else:
            raise ValueError(ffn)
    if cfg.cross_attention and mixer in ("attn", "mla"):
        p["norm_x"] = init_rmsnorm(cfg.d_model, cfg.param_dtype)
        p["cross"] = attn_mod.init_cross_attn(ks[2], cfg)
    return p


def init_block_cache(cfg: ModelConfig, mixer: str, ffn: str, batch: int,
                     max_len: int, dtype) -> dict:
    c: dict = {}
    if mixer == "attn":
        c["mixer"] = attn_mod.init_gqa_cache(cfg, batch, max_len, dtype)
    elif mixer == "mla":
        c["mixer"] = attn_mod.init_mla_cache(cfg, batch, max_len, dtype)
    elif mixer == "mamba2":
        c["mixer"] = ssm_mod.init_mamba2_cache(cfg, batch, dtype)
    elif mixer == "rwkv6":
        c["mixer"] = ssm_mod.init_rwkv6_cache(cfg, batch, dtype)
    if ffn == "rwkv_cm":
        c["cm_last"] = jnp.zeros((batch, 1, cfg.d_model), dtype)
    return c


def block_forward(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
                  cfg: ModelConfig, mixer: str, ffn: str, *,
                  cache: Optional[dict] = None,
                  cache_len: Optional[jnp.ndarray] = None,
                  enc: Optional[jnp.ndarray] = None,
                  ) -> Tuple[jnp.ndarray, Optional[dict], jnp.ndarray]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {} if cache is not None else None
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    mc = cache.get("mixer") if cache is not None else None

    if mixer == "attn":
        m, mc_new = attn_mod.gqa_forward(params["mixer"], h, positions, cfg,
                                         cache=mc, cache_len=cache_len)
    elif mixer == "mla":
        m, mc_new = attn_mod.mla_forward(params["mixer"], h, positions, cfg,
                                         cache=mc, cache_len=cache_len)
    elif mixer == "mamba2":
        m, mc_new = ssm_mod.mamba2_forward(params["mixer"], h, cfg, cache=mc)
    elif mixer == "rwkv6":
        m, mc_new = ssm_mod.rwkv6_forward(params["mixer"], h, cfg, cache=mc)
    else:
        raise ValueError(mixer)
    x = x + m
    if cache is not None:
        new_cache["mixer"] = mc_new

    if "cross" in params and enc is not None:
        hx = rmsnorm(params["norm_x"], x, cfg.norm_eps)
        x = x + attn_mod.cross_attn_forward(params["cross"], hx, enc, cfg)

    if ffn != "none":
        h2 = rmsnorm(params["norm2"], x, cfg.norm_eps)
        if ffn == "mlp":
            f = mlp_forward(params["ffn"], h2, cfg)
        elif ffn == "moe":
            f, aux = moe_mod.moe_forward(params["ffn"], h2, cfg)
        elif ffn == "rwkv_cm":
            last = cache.get("cm_last") if cache is not None else None
            f = ssm_mod.rwkv_cm_forward(params["ffn"], h2, cfg, last=last)
            if cache is not None:
                new_cache["cm_last"] = h2[:, -1:]
        else:
            raise ValueError(ffn)
        x = x + f
    return x, new_cache, aux
