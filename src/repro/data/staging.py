"""Double-buffered host->device chunk staging for the scan engines.

The fused/spmd engines execute a run as a sequence of pre-staged chunks
(``[rounds, k, E, B, ...]`` device tensors scanned by one jitted round
body).  Staging a chunk is pure host work — drawing minibatches through
the session's ``DataCursor``, filling the cohort-stacked buffer, and
dispatching the ``device_put`` into the per-cohort shardings — while
executing a chunk is pure device work, and JAX dispatch is asynchronous.
Running them back to back therefore idles the device during I/O and the
host during compute.

:class:`StagedChunkPipeline` overlaps the two: a background producer
thread stages chunk *n+1* while the jitted scan for chunk *n* runs,
bounded by a ``depth``-deep buffer pool (depth 2 = the classic double
buffer: one chunk in compute, one staged ahead).  The consumer releases
a buffer slot only once a chunk's compute results have been fetched, so
at most ``depth`` chunks of staged data are resident at any moment —
and the engine sizes its auto chunks with the staging budget divided by
the depth (``FusedEngine._auto_chunk_rounds``), so the resident total
stays within ``stage_budget_bytes`` rather than depth times it.

Determinism: the producer stages chunks strictly in plan order through
the *same* stage callable the serial path uses, so the ``DataCursor``
draw sequence — and therefore the training trajectory and the resume
bookkeeping — is bit-identical with the pipeline on or off
(tests/test_staging.py, tests/test_spmd_engine.py).

``overlap=False`` degrades to synchronous staging inside :meth:`get`
(no thread), which is both the kill switch (``REPRO_OVERLAP_STAGING=0``)
and the baseline leg of the overlap benchmark.
"""
from __future__ import annotations

import queue
import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence


@dataclass
class StageStats:
    """Wall-clock accounting for one pipeline run.

    ``stage_s`` is total producer time spent staging (draw + stack +
    device_put dispatch); ``wait_s`` is total consumer time blocked
    waiting for a chunk that was not ready.  Staging time not spent
    waiting was hidden behind compute, so the *overlap fraction* is
    ``1 - wait_s / stage_s`` (0 when nothing was hidden — e.g. the
    serial path, where the consumer waits for every staging in full)."""

    chunks: int = 0
    stage_s: float = 0.0
    wait_s: float = 0.0
    overlap: bool = field(default=False)

    @property
    def overlap_fraction(self) -> float:
        if self.stage_s <= 0.0:
            return 0.0
        return max(0.0, min(1.0, 1.0 - self.wait_s / self.stage_s))

    def as_dict(self) -> dict:
        return {"chunks": self.chunks, "stage_s": self.stage_s,
                "wait_s": self.wait_s, "overlap": self.overlap,
                "overlap_fraction": self.overlap_fraction}


class StagedChunkPipeline:
    """Bounded producer/consumer staging of a run's chunk plan.

    ``stage_fn(n)`` stages one ``n``-round chunk (the engine's
    ``_stage_chunk`` bound to the run's ``local_epochs``); ``plan`` is
    the run's chunk sizes in execution order.  The consumer protocol:

        pipeline = StagedChunkPipeline(stage_fn, plan)
        for n in plan:
            xs, ys = pipeline.get()       # blocks until chunk is staged
            ... dispatch the jitted scan on (xs, ys) ...
            ... fetch the previous chunk's losses ...
            pipeline.release()            # that chunk's buffers are dead
        pipeline.close()                  # also safe mid-run on error

    ``release()`` must be called once per completed chunk (it frees a
    buffer slot for the producer); ``close()`` is idempotent and must
    run on every exit path so the producer thread never outlives the
    run."""

    def __init__(self, stage_fn: Callable[[int], Any], plan: Sequence[int],
                 *, depth: int = 2, overlap: bool = True):
        if depth < 2:
            raise ValueError(f"pipeline depth must be >= 2 (one chunk in "
                             f"compute plus >= 1 staged ahead); got {depth}")
        self._stage_fn = stage_fn
        self._plan = list(plan)
        self._overlap = overlap
        self.stats = StageStats(overlap=overlap)
        self._serial_next = 0
        if not overlap:
            return
        self._q: queue.Queue = queue.Queue()
        self._slots = threading.Semaphore(depth)
        self._cancelled = threading.Event()
        self._thread = threading.Thread(
            target=self._produce, name="staged-chunk-producer", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------- producer
    def _produce(self) -> None:
        try:
            for n in self._plan:
                self._slots.acquire()
                if self._cancelled.is_set():
                    return
                t0 = time.perf_counter()
                chunk = self._stage_fn(n)
                dt = time.perf_counter() - t0
                # re-check after the (possibly long) stage_fn: once close()
                # has cancelled us, the consumer may already be reading
                # stats — stop mutating shared state and drawing from the
                # session's data cursor
                if self._cancelled.is_set():
                    return
                self.stats.stage_s += dt
                self._q.put((chunk, None))
        except BaseException as e:                        # noqa: BLE001
            # surface staging failures at the consumer's next get(), with
            # the original traceback chained
            self._q.put((None, e))

    # ------------------------------------------------------------- consumer
    def get(self) -> Any:
        """The next staged chunk, in plan order (blocks until ready)."""
        if not self._overlap:
            n = self._plan[self._serial_next]
            self._serial_next += 1
            t0 = time.perf_counter()
            chunk = self._stage_fn(n)
            dt = time.perf_counter() - t0
            self.stats.stage_s += dt
            self.stats.wait_s += dt       # serial: every staging is waited
            self.stats.chunks += 1
            return chunk
        t0 = time.perf_counter()
        chunk, err = self._q.get()
        self.stats.wait_s += time.perf_counter() - t0
        if err is not None:
            self.close()
            raise err
        self.stats.chunks += 1
        return chunk

    def release(self) -> None:
        """Mark one previously-``get``'d chunk's buffers dead (its compute
        results were fetched), freeing a slot for the producer."""
        if self._overlap:
            self._slots.release()

    def close(self) -> None:
        """Stop the producer (idempotent; safe on error paths)."""
        if not self._overlap:
            return
        self._cancelled.set()
        self._slots.release()             # unblock a producer parked on acquire
        self._thread.join(timeout=60.0)
        if self._thread.is_alive():
            # a stuck stage_fn: the daemon thread is still drawing from the
            # session's DataCursor, so stats may be incomplete and the
            # session must not run again in this process (the cursor's
            # draw bookkeeping would be corrupted)
            warnings.warn(
                "staged-chunk producer thread did not exit within 60s "
                "(stage_fn stuck?); staging stats may be incomplete and "
                "this session is unsafe to reuse until the thread dies",
                RuntimeWarning, stacklevel=2)
