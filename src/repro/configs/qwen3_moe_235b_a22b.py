"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4),
128 routed experts top-8 (d_expert=1536, no shared expert), vocab=151936.
[hf:Qwen/Qwen3-30B-A3B family scaled per assignment]"""
from __future__ import annotations

from repro.config import HeteroProfile, ModelConfig, MoEConfig

NUM_LAYERS = 94
EXITS = (23, 47, 70)


def config(sliding_window=None) -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", arch_type="moe",
        num_layers=NUM_LAYERS, d_model=4096, num_heads=64, num_kv_heads=4,
        d_ff=1536, vocab_size=151936, head_dim=128,
        ffn_pattern=("moe",) * NUM_LAYERS,
        moe=MoEConfig(num_experts=128, top_k=8, d_expert=1536,
                      capacity_factor=1.25),
        exit_layers=EXITS, sliding_window=sliding_window,
        source="hf:Qwen/Qwen3-30B-A3B",
    )


def smoke() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name="qwen3-moe-smoke", arch_type="moe",
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=32,
        ffn_pattern=("moe",) * 4,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64),
        exit_layers=(2,), dtype=jnp.float32, param_dtype=jnp.float32,
        source="hf:Qwen/Qwen3-30B-A3B",
    )


def profile() -> HeteroProfile:
    return HeteroProfile(split_layers=(EXITS[0],) * 4 + (EXITS[1],) * 4
                         + (EXITS[2],) * 4)
