"""Equivalence contract between the fused (scan+vmap) engine and the
paper-faithful reference engine, plus adaptive-inference threshold edges
shared by both engines.  See docs/ENGINES.md."""
import jax
import numpy as np
import pytest

from repro.config import HeteroProfile, OptimizerConfig, SplitEEConfig
from repro.core.fused import FusedHeteroTrainer
from repro.core.splitee import MLPSplitModel, stack_pytrees, unstack_pytrees
from repro.core.strategies import HeteroTrainer

TOL = 1e-5


def _blob_data(n, d, classes, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(classes, d)) * 2.0
    y = rng.integers(0, classes, n).astype(np.int32)
    x = (centers[y] + rng.normal(size=(n, d))).astype(np.float32)
    return x, y


def _make(cls, strategy, splits=(1, 2, 2, 3), aggregate_every=1):
    x, y = _blob_data(600, 16, 3)
    n = len(splits)
    model = MLPSplitModel(in_dim=16, hidden=32, num_classes=3, num_layers=4,
                          seed=0)
    parts = [(x[i::n], y[i::n]) for i in range(n)]
    tr = cls(model,
             SplitEEConfig(profile=HeteroProfile(tuple(splits)),
                           strategy=strategy,
                           aggregate_every=aggregate_every),
             OptimizerConfig(lr=3e-3, total_steps=50),
             parts, batch_size=64)
    return tr, (x, y)


def _assert_trees_close(a, b, msg=""):
    jax.tree.map(lambda u, v: np.testing.assert_allclose(
        np.asarray(u), np.asarray(v), atol=TOL, err_msg=msg), a, b)


def _assert_engines_match(ref, fus):
    assert len(ref.history) == len(fus.history)
    for a, b in zip(ref.history, fus.history):
        assert a.round == b.round
        assert abs(a.client_loss - b.client_loss) < TOL
        assert abs(a.server_loss - b.server_loss) < TOL
    for i in range(ref.N):
        _assert_trees_close(ref.clients[i]["trainable"],
                            fus.clients[i]["trainable"], f"client {i}")
        _assert_trees_close(ref.servers[i]["trainable"],
                            fus.servers[i]["trainable"], f"server {i}")
        _assert_trees_close((ref.client_opts[i].m, ref.client_opts[i].v),
                            (fus.client_opts[i].m, fus.client_opts[i].v),
                            f"client opt {i}")


# ---------------------------------------------------------------------------
# numerical equivalence to the reference engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ["averaging", "distributed"])
def test_fused_matches_reference(strategy):
    """≥3 rounds with E=2 local epochs: params, opt state and per-round
    metrics agree with the per-client reference to ~1e-5."""
    ref, _ = _make(HeteroTrainer, strategy)
    fus, _ = _make(FusedHeteroTrainer, strategy)
    ref.run(4, local_epochs=2)
    fus.run(4, local_epochs=2)
    _assert_engines_match(ref, fus)


def test_fused_matches_reference_aggregate_every_2():
    """aggregate_every=2: rounds 0/2 skip Eq. (1), rounds 1/3 apply it — the
    in-graph masked aggregation must hit exactly the reference boundaries."""
    ref, _ = _make(HeteroTrainer, "averaging", aggregate_every=2)
    fus, _ = _make(FusedHeteroTrainer, "averaging", aggregate_every=2)
    ref.run(4)
    fus.run(4)
    _assert_engines_match(ref, fus)
    # boundary really aggregated: deepest common layers identical
    for key in ("layer4", "head"):
        w0 = np.asarray(fus.servers[0]["trainable"][key]["w"])
        for s in fus.servers[1:]:
            np.testing.assert_allclose(w0, np.asarray(s["trainable"][key]["w"]),
                                       atol=1e-6)


def test_fused_chunked_matches_single_chunk():
    """Chunking the scan (chunk_rounds) must not change the trajectory."""
    one, _ = _make(FusedHeteroTrainer, "averaging", aggregate_every=2)
    many, _ = _make(FusedHeteroTrainer, "averaging", aggregate_every=2)
    one.run(6)
    many.run(6, chunk_rounds=2)
    _assert_engines_match(one, many)


def test_fused_rejects_sequential():
    with pytest.raises(ValueError, match="[Ss]equential"):
        _make(FusedHeteroTrainer, "sequential")


def test_fused_rejects_ragged_cohort_batches():
    """Two clients share a cut layer but batch_iterator clamps one shard
    below batch_size — lanes can't stack, so construction must fail loudly
    (the reference engine still handles this profile)."""
    x, y = _blob_data(200, 16, 3)
    model = MLPSplitModel(in_dim=16, hidden=32, num_classes=3, num_layers=4)
    parts = [(x[:100], y[:100]), (x[100:140], y[100:140])]   # 100 vs 40
    cfg = SplitEEConfig(profile=HeteroProfile((2, 2)), strategy="averaging")
    with pytest.raises(ValueError, match="batch"):
        FusedHeteroTrainer(model, cfg, OptimizerConfig(), parts,
                           batch_size=64)
    HeteroTrainer(model, cfg, OptimizerConfig(), parts,
                  batch_size=64).run(1)                      # oracle is fine


def test_stack_unstack_roundtrip():
    model = MLPSplitModel(in_dim=8, hidden=16, num_classes=3, num_layers=4)
    clients = [model.make_client(2) for _ in range(3)]
    stacked = model.stack_clients(clients)
    w = stacked["trainable"]["layers"]["layer1"]["w"]
    assert w.shape[0] == 3
    back = model.unstack(stacked, 3)
    for a, b in zip(clients, back):
        _assert_trees_close(a, b)
    # module-level helpers agree with the adapter methods
    _assert_trees_close(stack_pytrees(clients), stacked)
    for a, b in zip(unstack_pytrees(stacked, 3), back):
        _assert_trees_close(a, b)


# ---------------------------------------------------------------------------
# evaluate_adaptive threshold edges (both engines share the implementation)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cls", [HeteroTrainer, FusedHeteroTrainer])
def test_adaptive_tau_zero_is_pure_server(cls):
    """tau=0: entropy H >= 0 is never < 0, so nothing exits at the client —
    accuracy must equal the server-side path."""
    tr, (x, y) = _make(cls, "averaging")
    tr.run(3)
    ad = tr.evaluate_adaptive(x[:300], y[:300], tau=0.0, batch_size=100)
    assert ad["client_ratio"] == [0.0] * tr.N
    ev = tr.evaluate(x[:300], y[:300], batch_size=100)
    np.testing.assert_allclose(ad["acc"], ev["server_acc"], atol=1e-6)


@pytest.mark.parametrize("cls", [HeteroTrainer, FusedHeteroTrainer])
def test_adaptive_tau_above_max_entropy_is_pure_client(cls):
    """tau > log(num_classes) >= max H: every sample exits at the client."""
    tr, (x, y) = _make(cls, "averaging")
    tr.run(3)
    tau = float(np.log(3)) + 0.1
    ad = tr.evaluate_adaptive(x[:300], y[:300], tau=tau, batch_size=100)
    assert ad["client_ratio"] == [1.0] * tr.N
    ev = tr.evaluate(x[:300], y[:300], batch_size=100)
    np.testing.assert_allclose(ad["acc"], ev["client_acc"], atol=1e-6)
