"""Kernel-backend dispatch (``repro.kernels.dispatch``) — the PR's
equivalence gates.

Every routed hot site must agree between ``kernels="pallas"`` (Pallas
interpret mode on this CPU host — the same kernel program a TPU compiles)
and ``kernels="ref"`` (the pure-XLA code the call sites always ran):

  * the GQA attention contraction — train/prefill causal+window masks and
    the decode ring path with its traced ``kv_valid`` prefix — fwd + grad;
  * the RWKV6 chunked wkv recurrence (y AND the carried state) fwd + grad;
  * the Alg.-3 entropy gate (serve step and ServeSession);
  * end to end: fused-engine training metrics and ServeSession decode
    streams on the glm4-9b / rwkv6-3b smoke archs.

Tolerances here are the documented per-site gates (docs/ENGINES.md).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.config import (HeteroProfile, ModelConfig, OptimizerConfig,
                          SplitEEConfig)
from repro.kernels import dispatch

RNG = np.random.default_rng(7)

REF = dispatch.get_backend("ref")
PALLAS = dispatch.get_backend("pallas")


# ---------------------------------------------------------------------------
# registry / knob plumbing
# ---------------------------------------------------------------------------


def test_registry_names():
    assert dispatch.available_backends() == ("pallas", "ref")
    assert REF.name == "ref" and PALLAS.name == "pallas"
    assert isinstance(REF, dispatch.ReferenceBackend)
    assert isinstance(PALLAS, dispatch.PallasBackend)


def test_auto_resolution():
    # this suite runs on CPU: auto must pick the reference backend so the
    # default test/CI numerics stay bit-identical to pre-dispatch code
    assert jax.default_backend() != "tpu"
    assert dispatch.resolve_kernels("auto") == "ref"
    assert dispatch.resolve_kernels("auto", platform="tpu") == "pallas"
    assert dispatch.resolve_kernels("auto", platform="gpu") == "ref"
    # explicit names pass through regardless of platform
    assert dispatch.resolve_kernels("pallas") == "pallas"
    assert dispatch.resolve_kernels("ref", platform="tpu") == "ref"


def test_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown kernels backend"):
        dispatch.resolve_kernels("cuda")
    with pytest.raises(ValueError):
        dispatch.get_backend("cuda")


def test_config_knob_validated(tiny_dense):
    with pytest.raises(AssertionError):
        tiny_dense.with_(kernels="cuda")
    assert tiny_dense.with_(kernels="pallas").kernels == "pallas"


def test_backend_for_follows_cfg(tiny_dense):
    assert dispatch.backend_for(tiny_dense) is REF         # auto on CPU
    assert dispatch.backend_for(tiny_dense.with_(kernels="pallas")) is PALLAS
    assert dispatch.backend_for(object()) is REF           # no knob -> auto


def test_register_backend_later_wins():
    class Probe(dispatch.ReferenceBackend):
        name = "ref"

    probe = Probe()
    try:
        assert dispatch.register_backend(probe) is probe
        assert dispatch.get_backend("ref") is probe
    finally:
        dispatch.register_backend(REF)
    assert dispatch.get_backend("ref") is REF


# ---------------------------------------------------------------------------
# per-site parity: forward and gradient, pallas (interpret) vs ref
# ---------------------------------------------------------------------------


def _model_qkv(B=2, T=10, S=10, H=4, Hkv=2, hd=16):
    q = jnp.array(RNG.normal(size=(B, T, H, hd)), jnp.float32)
    k = jnp.array(RNG.normal(size=(B, S, Hkv, hd)), jnp.float32)
    v = jnp.array(RNG.normal(size=(B, S, Hkv, hd)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal,window", [(True, None), (True, 6),
                                           (False, None)])
def test_attention_site_fwd_and_grad(causal, window):
    q, k, v = _model_qkv()

    def loss(backend, q, k, v):
        out = backend.attention(q, k, v, causal=causal, window=window)
        return jnp.sum(out * out)

    for a, b in zip(jax.value_and_grad(lambda *x: loss(PALLAS, *x),
                                       argnums=(0, 1, 2))(q, k, v),
                    jax.value_and_grad(lambda *x: loss(REF, *x),
                                       argnums=(0, 1, 2))(q, k, v)):
        jax.tree.map(lambda x, y: np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), atol=1e-4, rtol=1e-3), a, b)


@pytest.mark.parametrize("n_valid", [1, 5, 12])
def test_attention_site_decode_kv_valid(n_valid):
    """The decode ring path: Tq=1 against a W-slot cache whose valid prefix
    is a traced scalar — must match the ref mask under jit."""
    q, k, v = _model_qkv(T=1, S=12)
    fp = jax.jit(lambda n: PALLAS.attention(q, k, v, kv_valid=n))
    fr = jax.jit(lambda n: REF.attention(q, k, v, kv_valid=n))
    n = jnp.int32(n_valid)
    np.testing.assert_allclose(np.asarray(fp(n)), np.asarray(fr(n)),
                               atol=2e-5, rtol=2e-4)


def test_wkv_site_fwd_state_and_grad():
    B, T, H, K, chunk = 2, 24, 2, 16, 8
    r = jnp.array(RNG.normal(size=(B, T, H, K)), jnp.float32)
    k = jnp.array(RNG.normal(size=(B, T, H, K)), jnp.float32)
    v = jnp.array(RNG.normal(size=(B, T, H, K)), jnp.float32)
    lw = -jnp.array(RNG.uniform(0.05, 1.0, size=(B, T, H, K)), jnp.float32)
    u = jnp.array(RNG.normal(size=(H, K)), jnp.float32)

    yp, sp = PALLAS.wkv(r, k, v, lw, u, chunk=chunk)
    yr, sr = REF.wkv(r, k, v, lw, u, chunk=chunk)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr), atol=1e-4,
                               rtol=1e-3)

    def loss(backend, *args):
        y, s = backend.wkv(*args, chunk=chunk)
        return jnp.sum(y * y) + jnp.sum(s * s)

    gp = jax.grad(lambda *x: loss(PALLAS, *x), argnums=(0, 1, 2, 3, 4))(
        r, k, v, lw, u)
    gr = jax.grad(lambda *x: loss(REF, *x), argnums=(0, 1, 2, 3, 4))(
        r, k, v, lw, u)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3,
                                   rtol=1e-2)


def test_entropy_gate_site():
    logits = jnp.array(RNG.normal(size=(3, 5, 257)) * 2, jnp.float32)
    tau = jnp.float32(0.7 * np.log(257))
    Hp, ep = PALLAS.entropy_gate(logits, tau)
    Hr, er = REF.entropy_gate(logits, tau)
    assert Hp.shape == er.shape == (3, 5)
    np.testing.assert_allclose(np.asarray(Hp), np.asarray(Hr), atol=1e-4,
                               rtol=1e-5)
    # decisions may differ only within float noise of the threshold
    borderline = np.abs(np.asarray(Hr) - float(tau)) < 1e-3
    np.testing.assert_array_equal(np.asarray(ep)[~borderline],
                                  np.asarray(er)[~borderline])


# ---------------------------------------------------------------------------
# model-layer parity: the actual call sites under the cfg knob
# ---------------------------------------------------------------------------


def _forward_pair(cfg, T=8, B=2):
    from repro.models.backbone import backbone_forward, init_backbone
    params = init_backbone(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                              cfg.vocab_size)
    outs = {}
    for kn in ("ref", "pallas"):
        outs[kn] = backbone_forward(params, cfg.with_(kernels=kn),
                                    tokens=toks)
    return params, toks, outs


@pytest.mark.parametrize("fixture", ["tiny_dense", "tiny_swa", "tiny_rwkv"])
def test_backbone_forward_parity(fixture, request):
    cfg = request.getfixturevalue(fixture)
    _, _, outs = _forward_pair(cfg)
    np.testing.assert_allclose(np.asarray(outs["pallas"].logits),
                               np.asarray(outs["ref"].logits), atol=5e-4,
                               rtol=1e-3)
    for ep, er in zip(outs["pallas"].exit_logits, outs["ref"].exit_logits):
        np.testing.assert_allclose(np.asarray(ep), np.asarray(er),
                                   atol=5e-4, rtol=1e-3)


@pytest.mark.parametrize("fixture", ["tiny_dense", "tiny_swa"])
def test_gqa_decode_ring_parity(fixture, request):
    """Prefill + 2 decode ticks against the ring cache: the routed decode
    path (traced ``kv_valid``) must track the ref stream tick for tick."""
    from repro.models.backbone import backbone_forward, init_backbone, \
        init_cache
    cfg = request.getfixturevalue(fixture)
    params = init_backbone(jax.random.PRNGKey(0), cfg)
    B, T = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T + 2), 0,
                              cfg.vocab_size)
    logits = {}
    for kn in ("ref", "pallas"):
        c = cfg.with_(kernels=kn)
        cache = init_cache(c, B, 16, jnp.float32)
        pre = backbone_forward(params, c, tokens=toks[:, :T], cache=cache,
                               cache_len=jnp.zeros((), jnp.int32))
        d1 = backbone_forward(params, c, tokens=toks[:, T : T + 1],
                              cache=pre.cache,
                              cache_len=jnp.full((), T, jnp.int32))
        d2 = backbone_forward(params, c, tokens=toks[:, T + 1 :],
                              cache=d1.cache,
                              cache_len=jnp.full((), T + 1, jnp.int32))
        logits[kn] = (pre.logits, d1.logits, d2.logits)
    for lp, lr in zip(logits["pallas"], logits["ref"]):
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lr),
                                   atol=5e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# end to end: fused-engine training and serving under the knob
# ---------------------------------------------------------------------------


def _train_pair(arch, rounds=2):
    from repro import configs as configs_mod
    from repro.api import TrainSession
    from repro.core.backbone_splitee import BackboneSplitModel
    from repro.data.pipeline import ClientPartitioner
    from repro.data.synthetic import SyntheticSeqClsDataset

    base = configs_mod.get(arch).smoke()
    cuts = sorted(base.exit_layers)
    splits = (cuts[0], cuts[-1])
    ds = SyntheticSeqClsDataset(vocab_size=base.vocab_size, seq_len=8,
                                num_classes=8, train_size=32, test_size=16,
                                seed=0)
    parts = ClientPartitioner(len(splits), seed=0).split(*ds.train)
    histories = {}
    for kn in ("ref", "pallas"):
        model = BackboneSplitModel(base.with_(kernels=kn), seed=0)
        sess = TrainSession.from_config(
            model, SplitEEConfig(profile=HeteroProfile(splits)),
            OptimizerConfig(lr=1e-3, total_steps=rounds + 4), parts,
            batch_size=8, engine="fused", seed=0)
        sess.train(rounds, log_every=0)
        histories[kn] = sess.history
    return histories


@pytest.mark.parametrize("arch", ["glm4-9b", "rwkv6-3b"])
def test_fused_training_parity(arch):
    """The acceptance gate: kernels="pallas" training on the fused engine
    reproduces kernels="ref" metrics within the documented tolerance on
    both smoke archs (attention-routed and wkv-routed)."""
    histories = _train_pair(arch)
    assert len(histories["pallas"]) == len(histories["ref"])
    for mp, mr in zip(histories["pallas"], histories["ref"]):
        np.testing.assert_allclose(mp.client_loss, mr.client_loss,
                                   atol=5e-3, rtol=5e-3)
        np.testing.assert_allclose(mp.server_loss, mr.server_loss,
                                   atol=5e-3, rtol=5e-3)


def test_serve_step_gate_parity():
    from repro import configs as configs_mod
    from repro.api.serve_session import serve_step_config
    from repro.core.spmd import make_serve_step
    from repro.models.backbone import init_backbone

    base = configs_mod.get("glm4-9b").smoke()
    tau = 0.9 * float(np.log(base.vocab_size))
    params = init_backbone(jax.random.PRNGKey(0), base)
    tokens = jnp.asarray(RNG.integers(0, base.vocab_size, (3, 4)), jnp.int32)
    got = {}
    for kn in ("ref", "pallas"):
        cfg = base.with_(kernels=kn)
        sc, _, _ = serve_step_config(cfg, tau=tau, boundary=0)
        got[kn] = make_serve_step(sc, boundary=0)(params, tokens, None, None)
    np.testing.assert_array_equal(
        np.argmax(np.asarray(got["pallas"]["logits"]), -1),
        np.argmax(np.asarray(got["ref"]["logits"]), -1))
    np.testing.assert_allclose(np.asarray(got["pallas"]["entropy"]),
                               np.asarray(got["ref"]["entropy"]), atol=1e-4,
                               rtol=1e-5)
    H = np.asarray(got["ref"]["entropy"])
    sure = np.abs(H - tau) > 1e-3
    np.testing.assert_array_equal(np.asarray(got["pallas"]["exited"])[sure],
                                  np.asarray(got["ref"]["exited"])[sure])


def test_serve_session_decode_parity():
    """Continuous-batching decode under kernels="pallas" streams the same
    tokens and gate decisions as kernels="ref"."""
    from repro import configs as configs_mod
    from repro.api.serve_session import ServeSession
    from repro.models.backbone import init_backbone

    base = configs_mod.get("glm4-9b").smoke()
    params = init_backbone(jax.random.PRNGKey(0), base)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, base.vocab_size, int(rng.integers(4, 9)))
               for _ in range(3)]
    results = {}
    for kn in ("ref", "pallas"):
        sess = ServeSession(base, params, tau=2.0, boundary=0, slots=2,
                            max_len=24, kernels=kn)
        assert sess.cfg.kernels == kn
        for p in prompts:
            sess.submit(p, decode_tokens=4)
        results[kn] = {r.rid: r for r in sess.run()}
    for rid in results["ref"]:
        rp, rr = results["pallas"][rid], results["ref"][rid]
        assert rp.tokens == rr.tokens, f"request {rid} tokens diverged"
        np.testing.assert_allclose(rp.entropy, rr.entropy, atol=1e-4)
        borderline = np.abs(np.asarray(rr.entropy) - 2.0) < 1e-3
        np.testing.assert_array_equal(np.asarray(rp.exited)[~borderline],
                                      np.asarray(rr.exited)[~borderline])
