"""Training driver: fused SPMD Hetero-SplitEE training of any registered
architecture on a jax mesh.

Two scales, same code path:
  * host demo (this container): ``--mesh host --host-shape 1,1`` over CPU
    devices, smoke-size configs, synthetic LM data — actually executes.
  * production: ``--mesh single|multi`` builds the 256/512-chip mesh (on the
    real cluster this runs; here it is exercised by dryrun.py which shares
    ``build_step_and_args``).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --smoke \
      --steps 20 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as configs_mod
from repro.checkpoint import load_pytree, save_pytree
from repro.config import (HeteroProfile, OptimizerConfig, SplitEEConfig,
                          TrainConfig)
from repro.core.spmd import StepConfig, boundary_ids_for_batch, make_train_step
from repro.data.synthetic import SyntheticLMDataset
from repro.models.backbone import init_backbone
from repro.optim import adam_init
from repro.launch.mesh import make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-mode", default="eq1", choices=["eq1", "sum"])
    ap.add_argument("--remat", default="none", choices=["none", "full"])
    ap.add_argument("--mesh", default="host", choices=["host", "single", "multi"])
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--resume", action="store_true",
                    help="continue from --checkpoint if it exists (restores "
                         "params, Adam moments and the step counter, and "
                         "skips the already-consumed data batches)")
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    mod = configs_mod.get(args.arch)
    cfg = mod.smoke() if args.smoke else mod.config()
    # hetero profile over this config's exit layers (paper: 12 clients, 4 per
    # depth); smoke configs may expose fewer exits.
    exits = cfg.exit_layers
    splits = tuple(np.repeat(exits, max(1, 12 // len(exits))))
    profile = HeteroProfile(split_layers=splits)

    sc = StepConfig(
        model=cfg,
        splitee=SplitEEConfig(profile=profile),
        train=TrainConfig(
            batch_size=args.batch, seq_len=args.seq, remat=args.remat,
            optimizer=OptimizerConfig(lr=args.lr, total_steps=args.steps,
                                      warmup_steps=0)),
        grad_mode=args.grad_mode)

    rng = jax.random.PRNGKey(args.seed)
    params = init_backbone(rng, cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name}  params={n_params/1e6:.1f}M  "
          f"devices={len(jax.devices())}  profile={profile.split_layers}")

    opt_state = adam_init(params, sc.train.optimizer)
    start_step = 0
    if args.resume and args.checkpoint and os.path.exists(
            args.checkpoint + ".npz"):
        with open(args.checkpoint + ".json") as f:
            manifest = json.load(f)
        saved_keys = manifest["keys"]
        saved_meta = manifest.get("metadata", {})
        # the resumed data stream is regenerated from (seed, batch, seq):
        # a mismatch would silently replay the WRONG batches — fail loudly
        for knob in ("arch", "batch", "seq", "seed"):
            want, have = saved_meta.get(knob), getattr(args, knob)
            if knob == "arch":
                have = cfg.name
            if want is not None and want != have:
                raise SystemExit(
                    f"--resume mismatch: checkpoint was written with "
                    f"{knob}={want!r} but this run has {knob}={have!r}")
        if any(k.startswith("['opt']") for k in saved_keys):
            restored = load_pytree(args.checkpoint,
                                   {"params": params, "opt": opt_state})
            params, opt_state = restored["params"], restored["opt"]
            start_step = int(opt_state.step)
            print(f"resumed {args.checkpoint}.npz at step {start_step}")
        else:
            # params-only checkpoint from before opt state was saved:
            # warm-start the weights, restart schedule/moments from step 0
            params = load_pytree(args.checkpoint, {"params": params})["params"]
            print(f"resumed {args.checkpoint}.npz (params only — predates "
                  f"optimizer-state checkpoints; restarting at step 0)")
    step_fn = jax.jit(make_train_step(sc))

    data = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=args.seq,
                              seed=args.seed)
    split_ids = boundary_ids_for_batch(profile, cfg, args.batch)

    t0 = time.time()
    for step, (toks, labels) in enumerate(
            data.batches(args.batch, args.steps)):
        if step < start_step:
            continue        # replay the seeded stream to the resume point
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels),
                 "split_ids": split_ids}
        if cfg.arch_type == "audio":
            batch["enc"] = jnp.zeros(
                (args.batch, min(args.seq, cfg.cross_source_len), 768),
                cfg.dtype)
        if cfg.arch_type == "vlm":
            from repro.models import frontend as fe
            P = min(fe.NUM_VISION_PATCHES, args.seq // 2)
            batch["embeds"] = jnp.zeros((args.batch, P, fe.SIGLIP_PATCH_DIM),
                                        cfg.dtype)
            batch["labels"] = jnp.asarray(
                np.concatenate([np.zeros((args.batch, P), np.int32), labels],
                               axis=1))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            print(f"step {step:5d}  server_loss {m['server_loss']:.4f}  "
                  f"client_losses "
                  + " ".join(f"{v:.3f}" for k, v in sorted(m.items())
                             if k.startswith("client_loss"))
                  + f"  lr {m['lr']:.2e}  [{dt:.1f}s]")

    if args.checkpoint:
        # opt state + step counter ride along so --resume continues the
        # cosine schedule and Adam moments exactly where this run stopped
        save_pytree(args.checkpoint, {"params": params, "opt": opt_state},
                    metadata={"arch": cfg.name, "steps": args.steps,
                              "batch": args.batch, "seq": args.seq,
                              "seed": args.seed})
        print(f"checkpoint -> {args.checkpoint}.npz")


if __name__ == "__main__":
    main()
