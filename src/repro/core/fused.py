"""Legacy ``FusedHeteroTrainer`` shim.

The scan+vmap multi-round engine now lives in
``repro.api.fused_engine.FusedEngine`` as a pure ``TrainState -> TrainState``
executor (see docs/ENGINES.md for the cohort layout, the in-graph Eq. (1)
aggregation, and the numerical-equivalence contract with the reference
engine).  This module keeps the historical import path working:
``FusedHeteroTrainer`` is a ``TrainSession`` shim pinned to the ``"fused"``
engine, so constructing it with the Sequential strategy or with ragged
cohort batch sizes still fails loudly at construction — use
``TrainSession(..., engine="auto")`` to fall back to the reference engine
instead.
"""
from __future__ import annotations

from typing import List

from repro.core.strategies import HeteroTrainer, RoundMetrics


class FusedHeteroTrainer(HeteroTrainer):
    """Deprecated: thin shim over ``repro.api.TrainSession`` pinned to the
    ``"fused"`` engine (averaging / distributed only)."""

    _ENGINE = "fused"

    def train_round(self, local_epochs: int = 1) -> RoundMetrics:
        """Single fused round (one-round chunk); prefer ``run`` for chunks."""
        return self.session.train(1, local_epochs)[-1]

    def run(self, rounds: int, local_epochs: int = 1, log_every: int = 0,
            chunk_rounds: int = 0) -> List[RoundMetrics]:
        """Train ``rounds`` rounds.  ``chunk_rounds`` bounds how many rounds
        of pre-staged data are resident at once (0 = the whole run is one
        compiled chunk).  Host sync happens once per chunk."""
        return self.session.run(rounds, local_epochs, log_every,
                                chunk_rounds=chunk_rounds)
