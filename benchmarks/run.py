"""Benchmark orchestrator — one benchmark per paper table/figure plus the
roofline readout.  Prints ``name,us_per_call,derived`` CSV (us_per_call =
wall time per cell; derived = the headline metric) and writes full JSON rows
to experiments/artifacts/.

  PYTHONPATH=src python -m benchmarks.run                # standard
  PYTHONPATH=src python -m benchmarks.run --quick        # CI-size
  PYTHONPATH=src python -m benchmarks.run --only table3_homo
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _emit(rows, csv_rows):
    for r in rows:
        name = r.get("name") or "/".join(
            str(r[k]) for k in ("table", "dataset", "method", "layer")
            if k in r)
        us = r.get("us_per_call", r.get("wall_s", 0) * 1e6)
        derived = r.get("derived", r.get("server_acc", r.get("acc",
                        r.get("dominant", r.get("max_err", "")))))
        csv_rows.append(f"{name},{us},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--artifacts", default="experiments/artifacts")
    args = ap.parse_args()

    os.makedirs(args.artifacts, exist_ok=True)
    kw = (dict(rounds=4, train_size=512, test_size=256, datasets=("syn10",))
          if args.quick else dict())
    fig2_kw = (dict(rounds=4, train_size=512, test_size=256, layers=(3,),
                    num_taus=9) if args.quick else dict())

    all_rows, csv_rows = [], ["name,us_per_call,derived"]
    t0 = time.time()

    def want(name):
        return not args.only or args.only == name

    if want("table3_homo"):
        from benchmarks import table3_homo
        rows = table3_homo.run(**kw)
        all_rows += rows
        _emit(rows, csv_rows)
    if want("table4_hetero"):
        from benchmarks import table4_hetero
        rows = table4_hetero.run(**kw)
        all_rows += rows
        _emit(rows, csv_rows)
    if want("fig2_threshold"):
        from benchmarks import fig2_threshold
        rows = fig2_threshold.run(**fig2_kw)
        all_rows += rows
        _emit(rows, csv_rows)
    if want("fused"):
        from benchmarks import fused_vs_reference
        rows = fused_vs_reference.run(
            out=os.path.join(args.artifacts, "BENCH_fused.json"),
            spmd_out=os.path.join(args.artifacts, "BENCH_spmd.json"),
            fsdp_out=os.path.join(args.artifacts, "BENCH_spmd_fsdp.json"),
            **(dict(rounds=8) if args.quick else dict()))
        all_rows += rows
        _emit(rows, csv_rows)
    if want("kernels"):
        from benchmarks import kernels_bench
        rows = kernels_bench.run()
        all_rows += rows
        _emit(rows, csv_rows)
    if want("roofline"):
        from benchmarks import roofline
        path = os.path.join(args.artifacts, "dryrun_baseline.jsonl")
        if os.path.exists(path):
            rows = roofline.run(path)
            all_rows += rows
            for r in rows:
                csv_rows.append(
                    f"roofline/{r['arch']}/{r['shape']},0,{r['dominant']}")
        else:
            csv_rows.append("roofline,-,missing (run repro.launch.dryrun)")

    out = os.path.join(args.artifacts, "bench_results.json")
    with open(out, "w") as f:
        json.dump(all_rows, f, indent=1)
    print("\n".join(csv_rows))
    print(f"# total wall {time.time() - t0:.1f}s; rows -> {out}",
          file=sys.stderr)


if __name__ == "__main__":
    main()
