"""Sharding recipes: spec construction on a small host-device mesh (runs in a
subprocess so the 8-device XLA flag never leaks into this process)."""
import json
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from jax.sharding import PartitionSpec as P
from repro import configs as configs_mod
from repro.launch import shardings as sh
from repro.launch.inputs import abstract_params, train_input_specs
from repro.config import ShapeConfig

mesh = jax.make_mesh((4, 2), ("data", "model"))
out = {}

cfg = configs_mod.get("glm4-9b").config()
params = abstract_params(cfg)
for scheme in ("greedy", "megatron"):
    rec = sh.ShardingRecipe(scheme=scheme)
    specs = sh.param_specs(params, cfg, mesh, rec)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    named = {"/".join(str(p) for p in path): str(spec)
             for path, spec in flat}
    # one representative leaf each
    out[scheme] = {
        "n_leaves": len(flat),
        "any_model": any("model" in s for s in named.values()),
        "embed": [s for k, s in named.items() if k.startswith("['embed']")][0],
    }

# megatron rules: wq sharded on heads, wo on heads (row), w_down on f
cfgm = configs_mod.get("command-r-35b").config()   # H=64 divisible
pm = abstract_params(cfgm)
specsm = sh.param_specs(pm, cfgm, mesh, sh.ShardingRecipe(scheme="megatron"))
seg = specsm["segments"][0][0]
out["mega_wq"] = str(seg["mixer"]["wq"])
out["mega_wo"] = str(seg["mixer"]["wo"])
out["mega_wdown"] = str(seg["ffn"]["w_down"])

# batch specs: divisible batch shards, batch=1 replicates
bs = sh.batch_specs({"tokens": jax.ShapeDtypeStruct((8, 16), jax.numpy.int32),
                     "one": jax.ShapeDtypeStruct((1, 16), jax.numpy.int32)},
                    mesh)
out["batch8"] = str(bs["tokens"]); out["batch1"] = str(bs["one"])
print(json.dumps(out))
"""


def test_sharding_recipes_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                        "JAX_PLATFORMS": "cpu"},
                       cwd=".", timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    for scheme in ("greedy", "megatron"):
        assert out[scheme]["any_model"], scheme
        assert out[scheme]["n_leaves"] > 20
    # megatron: wq (layer, d, H, hd) -> H on model; wo (layer, H, hd, d) ->
    # H on model (row); w_down (layer, f, d) -> f on model
    assert "'model'" in out["mega_wq"]
    assert "'model'" in out["mega_wo"]
    assert "'model'" in out["mega_wdown"]
    assert "'data'" in out["batch8"]
    assert out["batch1"] == "PartitionSpec()"
