"""Paper-faithful multi-client training engines.

``HeteroTrainer`` implements, literally per the pseudo-code:
  * **Sequential strategy (Algorithm 1)** — one shared server-side network;
    per round, each client runs E local minibatch steps (client-side loss on
    its exit head), and for each minibatch the server performs one update of
    the shared model on the transmitted features, with the server learning
    rate divided by N (paper Table II).
  * **Averaging strategy (Algorithm 2)** — client-specific server-side
    networks trained in parallel (order-independent), synchronized every
    round by cross-layer aggregation (Eq. 1).
  * **distributed** baseline — Averaging without aggregation (each client
    fully independent), the paper's lower bound.
  * **centralized** baseline — construct with a single client holding all
    data (the paper's upper bound, same hierarchical architecture).

Gradients never flow from server to client (``h_i`` enters the server step as
data), and every model is initialized from the same random seed via the
adapters in ``core/splitee.py``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import OptimizerConfig, SplitEEConfig
from repro.core.aggregation import cross_layer_aggregate, _mean_trees
from repro.core.losses import accuracy, softmax_cross_entropy, softmax_entropy
from repro.data.pipeline import batch_iterator
from repro.optim import adam_init, adam_update, make_schedule


@dataclass
class RoundMetrics:
    round: int
    client_loss: float
    server_loss: float


# ---------------------------------------------------------------------------
# Shared step-builders: pure functions of (pytrees, batch, lr), closed over the
# model/optimizer config only.  ``HeteroTrainer`` jits them one client at a
# time (the paper-faithful oracle); ``FusedHeteroTrainer`` (core/fused.py)
# vmaps the same functions over stacked client cohorts, so both engines run
# numerically identical math.
# ---------------------------------------------------------------------------


def make_client_step(model, opt_cfg: OptimizerConfig) -> Callable:
    """(trainable, state, opt, x, y, lr) ->
    (trainable, state, opt, h, loss) — Alg. 1/2 lines 6-11."""

    def loss_fn(trainable, state, x, y):
        h, logits, new_state = model.client_forward(trainable, state, x,
                                                    train=True)
        return softmax_cross_entropy(logits, y), (h, new_state)

    def step(trainable, state, opt, x, y, lr):
        (loss, (h, new_state)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(trainable, state, x, y)
        trainable, opt = adam_update(trainable, grads, opt, opt_cfg, lr)
        return trainable, new_state, opt, h, loss

    return step


def make_server_step(model, opt_cfg: OptimizerConfig, li: int) -> Callable:
    """(trainable, state, opt, h, y, lr) ->
    (trainable, state, opt, loss) — Alg. 1/2 lines 12-16; ``h`` enters as
    data, so no gradient ever flows back to the client."""

    def loss_fn(trainable, state, h, y):
        logits, new_state = model.server_forward(trainable, state, h, li,
                                                 train=True)
        return softmax_cross_entropy(logits, y), new_state

    def step(trainable, state, opt, h, y, lr):
        (loss, new_state), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(trainable, state, h, y)
        trainable, opt = adam_update(trainable, grads, opt, opt_cfg, lr)
        return trainable, new_state, opt, loss

    return step


class HeteroTrainer:
    """Drives one of the cooperative strategies over N heterogeneous clients."""

    def __init__(self, model, splitee_cfg: SplitEEConfig,
                 opt_cfg: OptimizerConfig,
                 client_data: Sequence[Tuple[np.ndarray, np.ndarray]],
                 batch_size: int, *, augment=None, seed: int = 0):
        self.model = model
        self.cfg = splitee_cfg
        self.opt_cfg = opt_cfg
        self.profile = splitee_cfg.profile
        self.N = self.profile.num_groups
        assert len(client_data) == self.N
        self.schedule = make_schedule(opt_cfg)
        self.strategy = splitee_cfg.strategy
        self.server_lr_div = splitee_cfg.resolved_server_lr_divisor()

        # --- clients -------------------------------------------------------
        self.clients = [model.make_client(li) for li in self.profile.split_layers]
        self.client_opts = [adam_init(c["trainable"], opt_cfg) for c in self.clients]
        self.iters = [
            batch_iterator(x, y, batch_size, seed=seed + i, augment=augment)
            for i, (x, y) in enumerate(client_data)
        ]

        # --- server(s) -----------------------------------------------------
        if self.strategy == "sequential":
            li_min = min(self.profile.split_layers)
            shared = model.make_server(li_min)
            self.servers = [shared] * 1            # one shared model
            self.server_opts = [adam_init(shared["trainable"], opt_cfg)]
        elif self.strategy in ("averaging", "distributed"):
            self.servers = [model.make_server(li)
                            for li in self.profile.split_layers]
            self.server_opts = [adam_init(s["trainable"], opt_cfg)
                                for s in self.servers]
        else:
            raise ValueError(self.strategy)

        self._cstep: Dict[int, Callable] = {}
        self._sstep: Dict[int, Callable] = {}
        self.history: List[RoundMetrics] = []
        self._round = 0

    # ------------------------------------------------------------------ jit
    def _client_step(self, li: int) -> Callable:
        # the client step is li-independent (the trainable's own layer keys
        # determine depth), so one jitted step serves every cohort
        if 0 not in self._cstep:
            self._cstep[0] = jax.jit(make_client_step(self.model,
                                                      self.opt_cfg))
        return self._cstep[0]

    def _server_step(self, li: int) -> Callable:
        if li not in self._sstep:
            self._sstep[li] = jax.jit(make_server_step(self.model,
                                                       self.opt_cfg, li))
        return self._sstep[li]

    # ------------------------------------------------------------ training
    def train_round(self, local_epochs: int = 1) -> RoundMetrics:
        t = self._round
        lr = self.schedule(t)
        lr_server = lr / self.server_lr_div
        closses, slosses = [], []

        for i, li in enumerate(self.profile.split_layers):
            cstep = self._client_step(li)
            sstep = self._server_step(li)
            sidx = 0 if self.strategy == "sequential" else i
            server = self.servers[sidx]
            sopt = self.server_opts[sidx]
            client, copt = self.clients[i], self.client_opts[i]

            for _ in range(local_epochs):
                x, y = next(self.iters[i])
                x, y = jnp.asarray(x), jnp.asarray(y)
                # client-side training (Alg. 1/2 lines 6-11)
                tr, st, copt, h, closs = cstep(client["trainable"],
                                               client["state"], copt, x, y, lr)
                client = {"trainable": tr, "state": st}
                # server-side training on h_i (lines 12-16); no grad to client
                h = jax.lax.stop_gradient(h)
                str_, sst, sopt, sloss = sstep(server["trainable"],
                                               server["state"], sopt, h, y,
                                               lr_server)
                server = {"trainable": str_, "state": sst}
                closses.append(float(closs))
                slosses.append(float(sloss))

            self.clients[i], self.client_opts[i] = client, copt
            self.servers[sidx], self.server_opts[sidx] = server, sopt

        # cross-layer aggregation (Alg. 2 lines 20-30)
        if (self.strategy == "averaging"
                and (t + 1) % self.cfg.aggregate_every == 0):
            self._aggregate()

        self._round += 1
        m = RoundMetrics(t, float(np.mean(closses)), float(np.mean(slosses)))
        self.history.append(m)
        return m

    def _aggregate(self) -> None:
        trainables = cross_layer_aggregate(
            [s["trainable"] for s in self.servers],
            list(self.profile.split_layers))
        # aggregate BN statistics of common layers the same way
        states = cross_layer_aggregate(
            [s["state"] for s in self.servers],
            list(self.profile.split_layers), extra_shared_keys=())
        self.servers = [{"trainable": tr, "state": st}
                        for tr, st in zip(trainables, states)]

    def run(self, rounds: int, local_epochs: int = 1,
            log_every: int = 0) -> List[RoundMetrics]:
        for _ in range(rounds):
            m = self.train_round(local_epochs)
            if log_every and (m.round % log_every == 0):
                print(f"round {m.round:4d}  client_loss {m.client_loss:.4f}  "
                      f"server_loss {m.server_loss:.4f}")
        return self.history

    # ---------------------------------------------------------------- eval
    def evaluate(self, x: np.ndarray, y: np.ndarray, batch_size: int = 512
                 ) -> Dict[str, Any]:
        """Per-client client-side and server-side test accuracy."""
        out = {"client_acc": [], "server_acc": [], "split_layers":
               list(self.profile.split_layers)}
        for i, li in enumerate(self.profile.split_layers):
            sidx = 0 if self.strategy == "sequential" else i
            ca, sa, n = 0.0, 0.0, 0
            for j in range(0, len(x) - batch_size + 1, batch_size):
                bx = jnp.asarray(x[j : j + batch_size])
                by = jnp.asarray(y[j : j + batch_size])
                h, clog, _ = self.model.client_forward(
                    self.clients[i]["trainable"], self.clients[i]["state"],
                    bx, train=False)
                slog, _ = self.model.server_forward(
                    self.servers[sidx]["trainable"], self.servers[sidx]["state"],
                    h, li, train=False)
                ca += float(accuracy(clog, by)) * len(bx)
                sa += float(accuracy(slog, by)) * len(bx)
                n += len(bx)
            out["client_acc"].append(ca / max(n, 1))
            out["server_acc"].append(sa / max(n, 1))
        return out

    def evaluate_adaptive(self, x: np.ndarray, y: np.ndarray, tau: float,
                          batch_size: int = 512) -> Dict[str, Any]:
        """Alg. 3 collaborative inference at entropy threshold ``tau``
        (exit iff H < tau; see DESIGN.md on the paper's sign convention)."""
        res = {"acc": [], "client_ratio": [], "mean_entropy": []}
        for i, li in enumerate(self.profile.split_layers):
            sidx = 0 if self.strategy == "sequential" else i
            correct, exits, ent_sum, n = 0.0, 0.0, 0.0, 0
            for j in range(0, len(x) - batch_size + 1, batch_size):
                bx = jnp.asarray(x[j : j + batch_size])
                by = np.asarray(y[j : j + batch_size])
                h, clog, _ = self.model.client_forward(
                    self.clients[i]["trainable"], self.clients[i]["state"],
                    bx, train=False)
                slog, _ = self.model.server_forward(
                    self.servers[sidx]["trainable"], self.servers[sidx]["state"],
                    h, li, train=False)
                H = np.asarray(softmax_entropy(clog))
                exit_mask = H < tau
                pred = np.where(exit_mask, np.asarray(jnp.argmax(clog, -1)),
                                np.asarray(jnp.argmax(slog, -1)))
                correct += float((pred == by).sum())
                exits += float(exit_mask.sum())
                ent_sum += float(H.sum())
                n += len(bx)
            res["acc"].append(correct / max(n, 1))
            res["client_ratio"].append(exits / max(n, 1))
            res["mean_entropy"].append(ent_sum / max(n, 1))
        return res
