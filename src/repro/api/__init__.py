"""Public training and serving API for the Hetero-SplitEE reproduction.

    from repro.api import TrainSession, ServeSession

    session = TrainSession.from_config(model, splitee_cfg, opt_cfg,
                                       client_data, batch_size=64)
    session.train(rounds=100, save_every=20, save_dir="ckpt/run1")

    serve = ServeSession.restore("ckpt/run1/ckpt-00000100", model,
                                 tau=1.5, slots=8, max_len=128)
    serve.submit(prompt_tokens); results = serve.run()

See docs/API.md.  Three registered engines — ``"reference"``, ``"fused"``,
``"spmd"`` — all pure ``TrainState -> TrainState`` executors behind this
facade; ``engine="auto"`` picks the widest one the session supports.
``ServeSession`` is the inference sibling: continuous-batching entropy-gated
decode straight from TrainSession checkpoints.
"""
from repro.api.engines import (AUTO_ORDER, Engine, SessionContext,  # noqa: F401
                               available_engines, get_engine,
                               register_engine, resolve_engine)
from repro.api.evaluation import SplitEvaluator, pad_batches  # noqa: F401
from repro.api.protocol import SplitModel, assert_split_model  # noqa: F401
from repro.api.serve_session import (ServeResult, ServeSession,  # noqa: F401
                                     ServeStats, resolve_serve_boundary,
                                     sequential_reference,
                                     sequential_sticky_reference,
                                     serve_step_config)
from repro.api.session import CHECKPOINT_FORMAT, TrainSession  # noqa: F401
from repro.api.state import TrainState, init_train_state  # noqa: F401
from repro.api.fused_engine import FusedEngine  # noqa: F401
from repro.api.reference_engine import ReferenceEngine  # noqa: F401
from repro.api.spmd_engine import SpmdEngine  # noqa: F401
