"""Trip-count-aware HLO analysis: a k-layer scan must report k x the
one-layer dot FLOPs (the property cost_analysis lacks)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze


def _scan_fn(k, grad=False):
    def body(x, w):
        return jnp.tanh(x @ w), None

    def fn(x, W):
        y, _ = jax.lax.scan(body, x, W)
        return y.sum()

    f = jax.grad(fn, argnums=1) if grad else fn
    return jax.jit(f).lower(jnp.zeros((8, 64)),
                            jnp.zeros((k, 64, 64))).compile().as_text()


@pytest.mark.parametrize("k", [1, 4, 16])
def test_scan_flops_scale_with_trip_count(k):
    a = analyze(_scan_fn(k))
    expect = 2 * 8 * 64 * 64 * k
    assert a["flops"] == pytest.approx(expect, rel=0.01)


def test_grad_scan_flops():
    a1 = analyze(_scan_fn(2, grad=True))
    a4 = analyze(_scan_fn(8, grad=True))
    assert a4["flops"] == pytest.approx(4 * a1["flops"], rel=0.02)


def test_nested_scan():
    def inner_body(x, w):
        return x @ w, None

    def outer_body(x, Ws):
        y, _ = jax.lax.scan(inner_body, x, Ws)
        return y, None

    def fn(x, W):
        y, _ = jax.lax.scan(outer_body, x, W)
        return y.sum()

    txt = jax.jit(fn).lower(jnp.zeros((8, 32)),
                            jnp.zeros((3, 5, 32, 32))).compile().as_text()
    a = analyze(txt)
    assert a["flops"] == pytest.approx(2 * 8 * 32 * 32 * 15, rel=0.01)


def test_collectives_counted_with_trips():
    # without a multi-device mesh there are no collectives; assert zero
    a = analyze(_scan_fn(4))
    assert a["collective_total"] == 0
