from repro.optim.adam import AdamState, adam_init, adam_update  # noqa: F401
from repro.optim.schedule import cosine_schedule, make_schedule  # noqa: F401
