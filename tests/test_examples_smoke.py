"""Tier-1 smoke test for the quickstart example: a 3-round TrainSession run
end-to-end (tiny MLP, CPU) so facade regressions — constructor signature,
engine auto-selection, train/evaluate/adaptive — fail fast in CI."""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from examples import quickstart  # noqa: E402


def test_quickstart_three_rounds():
    session = quickstart.main(rounds=3, log_every=0)
    # auto picked the widest available engine (fused on one device, spmd on
    # a multi-device host) and engine_name records the path taken
    assert session.engine.name in ("fused", "spmd")
    assert session.engine_name.startswith(session.engine.name)
    assert session.round == 3
    assert [m.round for m in session.history] == [0, 1, 2]
    assert all(np.isfinite([m.client_loss, m.server_loss])
               .all() for m in session.history)


def test_quickstart_reference_engine_override():
    session = quickstart.main(rounds=2, engine="reference", log_every=0)
    assert session.engine_name == "reference"
    assert session.round == 2
