"""Block-tiled causal flash attention (Pallas, TPU target).

Canonical TPU pattern: grid = (B, H, num_q_blocks, num_kv_blocks) with the
last grid axis sequential; running (max, denom, accum) live in VMEM scratch
across kv-block steps.  GQA is native — the k/v BlockSpec index maps query
head h to kv head h * Hkv // H, so grouped heads re-read the same kv block
(a local revisit, no HBM duplication).  Sliding windows skip blocks entirely
outside the band via ``pl.when``.

Default blocks (128, 128): MXU-aligned (contracting/lane dims multiples of
128); working set 4 x 128x128 x 4B ~= 256 KiB << 16 MiB VMEM, leaving head
room for double buffering.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(kv_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, block_q: int, block_k: int,
                  causal: bool, window: Optional[int], seq_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)
    kv_len = kv_ref[0, 0]                                    # traced valid-prefix

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k
    # block-level skip: entirely above the causal diagonal, entirely left of
    # the sliding window, entirely inside the key padding, or entirely past
    # the traced valid prefix (decode ring buffers attend kpos < kv_len).
    run = jnp.logical_and(k_start < seq_k, k_start < kv_len)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window is not None:
        run = jnp.logical_and(run, k_start + block_k > q_start - window + 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (Bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (Bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        # padded keys are masked unconditionally — the causal diagonal only
        # covers them when Tq == Tk, and non-causal shapes (the ServeSession
        # decode path, Tq != Tk) have no diagonal at all
        mask = jnp.logical_and(kpos < seq_k, kpos < kv_len)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, ...] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = 128, block_k: int = 128,
                           seq_k: Optional[int] = None,
                           kv_len: Optional[jnp.ndarray] = None,
                           interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, Tq, D); k/v: (B, Hkv, Tk, D), H % Hkv == 0.  Tq/Tk must be
    multiples of the block sizes (ops.py pads arbitrary shapes); ``seq_k``
    is the true (pre-padding) key length — keys at ``kpos >= seq_k`` are
    masked inside the kernel regardless of the causal/window setting.
    ``kv_len`` is an optional *traced* int32 scalar masking keys at
    ``kpos >= kv_len`` on top of the static masks — the decode ring-buffer
    valid prefix, varying per step without recompilation."""
    B, H, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    assert H % Hkv == 0 and Tq % block_q == 0 and Tk % block_k == 0
    seq_k = Tk if seq_k is None else seq_k
    assert 0 < seq_k <= Tk
    kv_len = (jnp.full((1, 1), seq_k, jnp.int32) if kv_len is None
              else jnp.asarray(kv_len, jnp.int32).reshape(1, 1))
    scale = 1.0 / math.sqrt(D)
    grid = (B, H, Tq // block_q, Tk // block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, seq_k=seq_k)

    kv_index = lambda b, h, iq, ik: (b, h * Hkv // H, ik, 0)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), kv_index),
            pl.BlockSpec((1, 1, block_k, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Tq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len, q, k, v)
