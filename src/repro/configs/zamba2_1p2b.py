"""zamba2-1.2b [hybrid] — 38L d_model=2048, Mamba2 backbone with a single
globally-shared attention(+MLP) block applied every 6th layer; shared block:
32H (kv=32) d_ff=8192; ssm_state=64; vocab=32000.  [arXiv:2411.15242]"""
from __future__ import annotations

from repro.config import HeteroProfile, ModelConfig, SSMConfig

NUM_LAYERS = 38
SHARED_EVERY = 6
EXITS = (10, 20, 29)


def _patterns(num_layers: int, shared_every: int):
    blocks, ffns = [], []
    for l in range(num_layers):
        if (l + 1) % shared_every == 0:
            blocks.append("shared_attn")
            ffns.append("mlp")
        else:
            blocks.append("mamba2")
            ffns.append("none")
    return tuple(blocks), tuple(ffns)


def config(sliding_window=None) -> ModelConfig:
    blocks, ffns = _patterns(NUM_LAYERS, SHARED_EVERY)
    return ModelConfig(
        name="zamba2-1.2b", arch_type="hybrid",
        num_layers=NUM_LAYERS, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=32000, head_dim=64,
        block_pattern=blocks, ffn_pattern=ffns,
        ssm=SSMConfig(kind="mamba2", d_state=64, d_conv=4, expand=2,
                      head_dim=64, chunk_size=256),
        exit_layers=EXITS, sliding_window=sliding_window,
        source="arXiv:2411.15242",
    )


def smoke() -> ModelConfig:
    import jax.numpy as jnp
    blocks, ffns = _patterns(4, 3)
    return ModelConfig(
        name="zamba2-1.2b-smoke", arch_type="hybrid",
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512, head_dim=32,
        block_pattern=blocks, ffn_pattern=ffns,
        ssm=SSMConfig(kind="mamba2", d_state=16, d_conv=4, expand=2,
                      head_dim=32, chunk_size=8),
        exit_layers=(2,), dtype=jnp.float32, param_dtype=jnp.float32,
        source="arXiv:2411.15242",
    )


def profile() -> HeteroProfile:
    return HeteroProfile(split_layers=(EXITS[0],) * 4 + (EXITS[1],) * 4
                         + (EXITS[2],) * 4)
