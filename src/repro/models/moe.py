"""Mixture-of-experts FFN with top-k routing and capacity-based dispatch.

Production path (``moe_forward``): sort-based token->expert dispatch into
per-expert capacity buffers — the classic TPU formulation (Switch/GShard
lineage).  Tokens are flattened, replicated top_k times, sorted by expert id,
and scattered into an (E, C, d) buffer that is sharded over the mesh's expert
axes; XLA lowers the gather/scatter across shards to all-to-all collectives.
Expert FFNs then run as one batched (E,·,·) matmul on the MXU.  Tokens beyond
an expert's capacity ``C = ceil(N * top_k / E * capacity_factor)`` are dropped
(their combine weight contributes zero), exactly as in capacity-factor MoE.

``moe_forward_dense`` is the O(N*E) einsum oracle used by the test-suite to
validate the dispatch path on small shapes.

The aux load-balance loss follows Switch: E * sum_e f_e * P_e.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, MoEConfig
from repro.models import sharding_ctx
from repro.models.common import activation, fan_in_init
from repro.models.mlp import init_mlp, mlp_forward


def init_moe(rng, cfg: ModelConfig) -> dict:
    m: MoEConfig = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(rng, 5)
    p = {
        "router": fan_in_init(ks[0], (d, m.num_experts), jnp.float32),
        # stacked expert weights: (E, d, d_expert) / (E, d_expert, d)
        "w_gate": fan_in_init(ks[1], (m.num_experts, d, m.d_expert),
                              cfg.param_dtype, fan_in=d),
        "w_up": fan_in_init(ks[2], (m.num_experts, d, m.d_expert),
                            cfg.param_dtype, fan_in=d),
        "w_down": fan_in_init(ks[3], (m.num_experts, m.d_expert, d),
                              cfg.param_dtype, fan_in=m.d_expert),
    }
    if m.num_shared_experts > 0:
        p["shared"] = init_mlp(ks[4], cfg,
                               d_ff=m.d_shared_expert * m.num_shared_experts)
    return p


def route(params: dict, x: jnp.ndarray, m: MoEConfig
          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (N, d) flat tokens -> (top_idx (N,k), top_w (N,k), aux loss)."""
    logits = jnp.einsum("nd,de->ne", x.astype(m.router_dtype),
                        params["router"].astype(m.router_dtype))
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, m.top_k)                 # (N, k)
    topv = topv / jnp.clip(topv.sum(-1, keepdims=True), 1e-9)  # renormalize
    # Switch-style load balance loss.
    N = x.shape[0]
    f = jnp.zeros((m.num_experts,), jnp.float32).at[topi.reshape(-1)].add(
        1.0 / (N * m.top_k))
    P = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(f * P) * m.router_aux_weight
    return topi, topv.astype(x.dtype), aux


def expert_capacity(num_tokens: int, m: MoEConfig) -> int:
    c = math.ceil(num_tokens * m.top_k / m.num_experts * m.capacity_factor)
    return max(4, int(c))


def moe_forward(params: dict, x: jnp.ndarray, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, d) -> (out (B,T,d), aux_loss scalar)."""
    m: MoEConfig = cfg.moe
    B, T, d = x.shape
    N = B * T
    k, E = m.top_k, m.num_experts
    C = expert_capacity(N, m)
    act = activation(cfg.act)
    xf = x.reshape(N, d)

    topi, topw, aux = route(params, xf, m)

    # ---- sort-based dispatch ------------------------------------------------
    flat_e = topi.reshape(-1)                                   # (N*k,)
    order = jnp.argsort(flat_e, stable=True)                    # (N*k,)
    sorted_e = flat_e[order]
    sorted_tok = (jnp.arange(N * k, dtype=jnp.int32) // k)[order]
    # rank of each entry within its expert's run
    starts = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=sorted_e.dtype))
    rank = jnp.arange(N * k, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    keep = rank < C                                             # capacity drop
    slot = sorted_e.astype(jnp.int32) * C + jnp.clip(rank, 0, C - 1)  # (N*k,)

    gathered = jnp.where(keep[:, None], xf[sorted_tok], 0)      # (N*k, d)
    gathered = sharding_ctx.constrain(gathered, "data", "model")
    buf = jnp.zeros((E * C, d), x.dtype).at[slot].set(
        gathered, mode="drop", unique_indices=False)
    buf = buf.reshape(E, C, d)
    # expert-parallel placement of the dispatch buffer (all-to-all happens
    # here, not as repeated all-gathers downstream); candidates tried in
    # order of divisibility: full grid, then data-only expert parallelism.
    buf = sharding_ctx.constrain(buf, [("data", "model"), "data"], None,
                                 [None, "model"])

    # ---- expert FFN (batched over E; MXU matmuls) ---------------------------
    gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = act(gate) * up
    eout = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    eout = sharding_ctx.constrain(eout, [("data", "model"), "data"], None,
                                  [None, "model"]).reshape(E * C, d)

    # ---- combine back -------------------------------------------------------
    w_sorted = topw.reshape(-1)[order]                          # (N*k,)
    contrib = eout[slot] * (w_sorted * keep)[:, None]
    out = jnp.zeros((N, d), x.dtype).at[sorted_tok].add(contrib)

    if "shared" in params:
        out = out.reshape(B, T, d) + mlp_forward(params["shared"], x, cfg)
        return out.astype(x.dtype), aux
    return out.reshape(B, T, d).astype(x.dtype), aux


def moe_forward_dense(params: dict, x: jnp.ndarray, cfg: ModelConfig
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """O(N*E) einsum oracle (no capacity drops) for test validation."""
    m: MoEConfig = cfg.moe
    B, T, d = x.shape
    act = activation(cfg.act)
    xf = x.reshape(B * T, d)
    topi, topw, aux = route(params, xf, m)
    combine = jnp.zeros((B * T, m.num_experts), x.dtype)
    combine = jnp.put_along_axis(combine, topi, topw, axis=-1, inplace=False)
    gate = jnp.einsum("nd,edf->nef", xf, params["w_gate"])
    up = jnp.einsum("nd,edf->nef", xf, params["w_up"])
    h = act(gate) * up
    eout = jnp.einsum("nef,efd->ned", h, params["w_down"])
    out = jnp.einsum("ned,ne->nd", eout, combine).reshape(B, T, d)
    if "shared" in params:
        out = out + mlp_forward(params["shared"], x, cfg)
    return out.astype(x.dtype), aux
