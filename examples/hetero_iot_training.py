"""The paper's experimental scenario end-to-end: 12 heterogeneous IoT clients
(4x end_layer=3, 4x end_layer=4, 4x end_layer=5) collaboratively train the
Table-I ResNet-18 on a CIFAR-stand-in dataset, comparing the Sequential
strategy (Alg. 1), the Averaging strategy (Alg. 2) and the Distributed
baseline.

Reduced scale for CPU (width-0.25 ResNet, small synthetic dataset, few
rounds); pass --rounds/--train-size for bigger runs.

  PYTHONPATH=src python examples/hetero_iot_training.py --rounds 8
"""
import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import make_dataset, mean_by_depth, run_strategy  # noqa: E402
from repro.configs.resnet18_cifar import HETERO_SPLITS  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--train-size", type=int, default=1024)
    ap.add_argument("--dataset", default="syn100",
                    choices=["syn10", "syn100", "synstl"])
    ap.add_argument("--engine", default="auto",
                    help="TrainSession engine: auto | reference | fused "
                         "(auto picks fused for averaging/distributed and "
                         "falls back to reference for sequential)")
    args = ap.parse_args()

    ds = make_dataset(args.dataset, args.train_size, 512)
    print(f"dataset={args.dataset}  12 clients, splits {HETERO_SPLITS}\n")
    print(f"{'method':13s} {'depth':5s} {'client':>7s} {'server':>7s}")
    for method in ("sequential", "averaging", "distributed"):
        ev = run_strategy(ds, method, HETERO_SPLITS, rounds=args.rounds,
                          engine=args.engine)
        by = mean_by_depth(ev, HETERO_SPLITS)
        for li, accs in sorted(by.items()):
            print(f"{method:13s} L={li:3d} {accs['client']:7.3f} "
                  f"{accs['server']:7.3f}")
        print()


if __name__ == "__main__":
    main()
