"""Public training API for the Hetero-SplitEE reproduction.

    from repro.api import TrainSession

    session = TrainSession.from_config(model, splitee_cfg, opt_cfg,
                                       client_data, batch_size=64)
    session.train(rounds=100)
    session.save("ckpt/run1")

See docs/API.md.  The legacy ``HeteroTrainer``/``FusedHeteroTrainer``
classes in ``repro.core`` are deprecation shims over this facade.
"""
from repro.api.engines import (AUTO_ORDER, Engine, SessionContext,  # noqa: F401
                               available_engines, get_engine,
                               register_engine, resolve_engine)
from repro.api.evaluation import SplitEvaluator, pad_batches  # noqa: F401
from repro.api.protocol import SplitModel, assert_split_model  # noqa: F401
from repro.api.session import CHECKPOINT_FORMAT, TrainSession  # noqa: F401
from repro.api.state import TrainState, init_train_state  # noqa: F401
from repro.api.fused_engine import FusedEngine  # noqa: F401
from repro.api.reference_engine import ReferenceEngine  # noqa: F401
