"""Sharding recipes: PartitionSpec trees for params, optimizer state, batches
and caches — for the offline dry-run AND the live spmd engine (the two share
one rule set; :func:`train_state_specs` is the engine's entry point).

Scheme (MaxText-style, tunable via ``ShardingRecipe`` for the §Perf loop):
  * batch dims shard over ("pod","data") when divisible, else replicate;
  * cohort-stacked engine carries (leading lane dim ``E``) shard the lane
    dim over the mesh's ``"lanes"`` axis when divisible;
  * 2D+ weights: tensor-parallel shard the largest divisible dim over
    "model"; with FSDP on, additionally shard the largest remaining divisible
    dim over the fsdp axes;
  * MoE expert stacks (leading dim == num_experts): expert-parallel —
    E over ("data","model") when it matches the full grid (DeepSeek's 256),
    otherwise E over "data" with the expert hidden dim over "model";
  * stacked-run leaves (leading layer axis from the backbone scan) never
    shard the layer-stack dim;
  * 1D / tiny params (``min_shard_elems``) replicate (the lane dim still
    shards: lane sharding is pure cohort parallelism, never a collective
    inside a step).
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple, Union

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig
from repro.launch.mesh import LANE_AXIS, axis_sizes, batch_axes


@dataclass(frozen=True)
class ShardingRecipe:
    scheme: str = "greedy"               # greedy | megatron | hybrid
    tp_axis: str = "model"
    fsdp: bool = True
    fsdp_axes: Tuple[str, ...] = ("data",)
    expert_mode: str = "auto"            # auto | data | grid
    min_shard_elems: int = 1 << 16       # replicate tiny leaves
    shard_cache_seq: bool = True         # shard decode cache seq dim on model
    shard_lanes: bool = True             # cohort lane dim over the lanes axis


#: the recipes the CLI / session accept by name (``--recipe`` in
#: launch/train.py).  "replicate" is the pre-recipe spmd engine behavior:
#: batch-only sharding, everything else replicated.
NAMED_RECIPES: Dict[str, ShardingRecipe] = {
    "greedy": ShardingRecipe(),
    "megatron": ShardingRecipe(scheme="megatron"),
    "hybrid": ShardingRecipe(scheme="hybrid"),
    "fsdp-off": ShardingRecipe(fsdp=False),
    "replicate": ShardingRecipe(fsdp=False, shard_lanes=False,
                                min_shard_elems=1 << 62),
}


def resolve_recipe(recipe: Union[str, ShardingRecipe, None]
                   ) -> ShardingRecipe:
    """Name / instance / None -> a concrete :class:`ShardingRecipe`
    (``None`` means the default "greedy" recipe)."""
    if recipe is None:
        return NAMED_RECIPES["greedy"]
    if isinstance(recipe, ShardingRecipe):
        return recipe
    try:
        return NAMED_RECIPES[recipe]
    except (KeyError, TypeError):
        raise ValueError(
            f"unknown sharding recipe {recipe!r}; named recipes: "
            f"{sorted(NAMED_RECIPES)} (or pass a ShardingRecipe)") from None


def recipe_name(recipe: Union[str, ShardingRecipe, None]) -> str:
    """The manifest-facing name: the matching registry name, else
    "custom"."""
    if recipe is None:
        return "greedy"
    if isinstance(recipe, str):
        return recipe
    for name, r in NAMED_RECIPES.items():
        if r == recipe:
            return name
    return "custom"


def recipe_to_meta(recipe: ShardingRecipe) -> dict:
    """JSON-able checkpoint metadata for a recipe."""
    d = dataclasses.asdict(recipe)
    d["fsdp_axes"] = list(d["fsdp_axes"])
    return d


def recipe_from_meta(meta: dict) -> ShardingRecipe:
    d = dict(meta)
    d["fsdp_axes"] = tuple(d.get("fsdp_axes", ("data",)))
    return ShardingRecipe(**d)


def default_recipe(cfg: ModelConfig, mesh) -> ShardingRecipe:
    return ShardingRecipe()


# Megatron-style name rules: which named dim to tensor-parallel shard.
# (param-name, dim-index-after-optional-layer-stack) -> role
#   "col": shard an OUTPUT dim (column parallel, no comm in fwd matmul)
#   "row": shard the CONTRACTING dim (row parallel, one all-reduce after)
_MEGATRON_RULES = {
    # attention (wq/wk/wv: (d, H, hd); wo: (H, hd, d))
    "wq": ("col", 1), "wk": ("col", 1), "wv": ("col", 1), "wo": ("row", 0),
    # MLA (latent down-projections are column-sharded too — leaving them
    # replicated cost 1.55x collective bytes in §Perf iteration 1)
    "w_uq": ("col", 1), "w_uk": ("col", 1), "w_uv": ("col", 1),
    "w_dq": ("col", 1), "w_dkv": ("col", 1),
    # SwiGLU mlp (w_gate/w_up: (d, f); w_down: (f, d))
    "w_gate": ("col", 1), "w_up": ("col", 1), "w_down": ("row", 0),
    # embeddings / heads (table: (V, d); head w: (d, V))
    "table": ("col", 0), "w": ("col", 1),
    # rwkv time-mix (wr/wk/wv/wg: (d, d) -> col; wo (d, d) -> row)
    "wg": ("col", 1),
    # mamba2 (in_proj output dim is a concat of z/xBC/dt -> leave to fsdp)
    "in_proj": (None, None), "out_proj": (None, None),
    "w_lora_a": (None, None), "w_lora_b": (None, None),
}


# ---------------------------------------------------------------------------
# leaf rules
# ---------------------------------------------------------------------------


def _pick_dim(shape, size, skip=(), taken=()):
    """Largest dim divisible by ``size``, excluding ``skip``/``taken``."""
    best, best_dim = 0, None
    for i, s in enumerate(shape):
        if i in skip or i in taken:
            continue
        if s % size == 0 and s > best:
            best, best_dim = s, i
    return best_dim


def _leaf_spec(leaf, sizes: Dict[str, int], recipe: ShardingRecipe,
               skip_dim0: bool, is_expert: bool, num_experts: int,
               name: str = "", skip_dims: Optional[Tuple[int, ...]] = None):
    shape = leaf.shape
    if leaf.size < recipe.min_shard_elems or leaf.ndim < 2:
        return P()
    spec = [None] * leaf.ndim
    # ``skip_dims`` (a contiguous leading prefix: lane and/or layer-stack
    # dims) generalizes the historical skip_dim0 flag
    skip = skip_dims if skip_dims is not None else ((0,) if skip_dim0 else ())
    lead = (max(skip) + 1) if skip else 0   # first "real" dim after stacking

    if is_expert:
        grid = sizes.get("data", 1) * sizes.get(recipe.tp_axis, 1)
        e_dim = lead

        def pod_fsdp():
            # 3-axis FSDP: shard one remaining dim over "pod" when enabled
            if (recipe.fsdp and "pod" in recipe.fsdp_axes
                    and sizes.get("pod", 1) > 1):
                fd = _pick_dim(shape, sizes["pod"], skip=skip + (e_dim,),
                               taken=tuple(i for i, s in enumerate(spec)
                                           if s is not None))
                if fd is not None:
                    spec[fd] = "pod"

        if (recipe.expert_mode in ("auto", "grid")
                and num_experts % grid == 0 and grid > 1):
            spec[e_dim] = ("data", recipe.tp_axis)
            pod_fsdp()
            return P(*spec)
        if num_experts % sizes.get("data", 1) == 0:
            spec[e_dim] = "data"
            tp = _pick_dim(shape, sizes.get(recipe.tp_axis, 1),
                           skip=skip + (e_dim,))
            if tp is not None:
                spec[tp] = recipe.tp_axis
            pod_fsdp()
            return P(*spec)
        # fall through to generic rules

    tp_size = sizes.get(recipe.tp_axis, 1)
    if recipe.scheme in ("megatron", "hybrid"):
        rule = _MEGATRON_RULES.get(name)
        tp_dim = None
        if rule and rule[0] is not None:
            cand = rule[1] + lead
            if cand < leaf.ndim and shape[cand] % tp_size == 0:
                tp_dim = cand
        # megatron: rule None or indivisible (e.g. 40 heads on a 16-way
        # axis) -> replicate the TP dim and rely on FSDP (collective-free
        # contractions, but compute replicates across the model axis).
        # hybrid: fall back to the greedy pick instead (pays the partial-sum
        # all-reduce, keeps compute sharded) — §Perf iteration 3.
        if tp_dim is None and recipe.scheme == "hybrid":
            tp_dim = _pick_dim(shape, tp_size, skip=skip)
    else:
        tp_dim = _pick_dim(shape, tp_size, skip=skip)
    if tp_dim is not None and tp_size > 1:
        spec[tp_dim] = recipe.tp_axis
    else:
        tp_dim = None          # an inert 1-way TP pick must not block FSDP
    if recipe.fsdp:
        fsdp_size = int(np.prod([sizes.get(a, 1) for a in recipe.fsdp_axes]))
        if fsdp_size > 1:
            fd = _pick_dim(shape, fsdp_size, skip=skip,
                           taken=() if tp_dim is None else (tp_dim,))
            if fd is not None:
                ax = (recipe.fsdp_axes if len(recipe.fsdp_axes) > 1
                      else recipe.fsdp_axes[0])
                spec[fd] = ax
    return P(*spec)


# ---------------------------------------------------------------------------
# tree builders
# ---------------------------------------------------------------------------


def param_specs(abstract_params: Any, cfg: ModelConfig, mesh,
                recipe: Optional[ShardingRecipe] = None):
    """PartitionSpec tree matching the backbone parameter structure."""
    recipe = recipe or default_recipe(cfg, mesh)
    sizes = axis_sizes(mesh)
    n_exp = cfg.moe.num_experts if cfg.moe else -1

    def walk(tree, skip_dim0):
        def visit(path, leaf):
            keys = [getattr(p, "key", "") for p in path if hasattr(p, "key")]
            name = keys[-1] if keys else ""
            is_expert = (n_exp > 1 and leaf.ndim >= 2
                         and leaf.shape[int(skip_dim0)] == n_exp
                         and any(k in ("w_gate", "w_up", "w_down")
                                 for k in keys))
            return _leaf_spec(leaf, sizes, recipe, skip_dim0, is_expert,
                              n_exp, name=name)
        return jax.tree_util.tree_map_with_path(visit, tree)

    specs = {}
    for key, sub in abstract_params.items():
        if key == "segments":
            specs[key] = [
                [walk(run_p, skip_dim0=_is_stacked(run_p))
                 for run_p in seg]
                for seg in sub
            ]
        else:
            specs[key] = walk(sub, skip_dim0=False)
    return specs


def _is_stacked(run_params) -> bool:
    """A stacked run has every leaf sharing the same leading (layer) dim and
    norm scales of ndim 2 instead of 1."""
    leaves = jax.tree.leaves(run_params)
    if not leaves:
        return False
    # norm scales are 1-D in a single block, 2-D when stacked
    min_ndim = min(l.ndim for l in leaves)
    return min_ndim >= 2 and len({l.shape[0] for l in leaves}) == 1


def batch_specs(input_specs: Dict[str, Any], mesh):
    """Shard batch dims over ("pod","data") where divisible."""
    axes = batch_axes(mesh)
    sizes = axis_sizes(mesh)
    dp = int(np.prod([sizes[a] for a in axes]))
    ax = axes if len(axes) > 1 else axes[0]

    def visit(leaf):
        if leaf.ndim == 0 or leaf.shape[0] % dp != 0:
            return P()
        return P(ax, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(visit, input_specs)


def cache_specs(cache_abstract: Any, cfg: ModelConfig, mesh,
                recipe: Optional[ShardingRecipe] = None):
    """Decode caches: batch dim over ("pod","data") when divisible; the
    sequence/window dim over "model" when divisible (k/v/ckv buffers)."""
    recipe = recipe or default_recipe(cfg, mesh)
    axes = batch_axes(mesh)
    sizes = axis_sizes(mesh)
    dp = int(np.prod([sizes[a] for a in axes]))
    tp = sizes.get(recipe.tp_axis, 1)
    ax = axes if len(axes) > 1 else axes[0]

    def visit(leaf):
        if leaf.ndim < 2:
            return P()
        spec = [None] * leaf.ndim
        # stacked run caches have a leading layer dim; batch is dim 0 or 1
        bdim = 0
        if leaf.ndim >= 3 and leaf.shape[0] <= 128 and leaf.shape[1] != 1:
            # heuristics fail-safe: treat dim0 as layer-stack only when the
            # batch dim divides dp at dim1 but not dim0
            if leaf.shape[0] % dp != 0 and leaf.shape[1] % dp == 0:
                bdim = 1
        if leaf.shape[bdim] % dp == 0:
            spec[bdim] = ax
        if recipe.shard_cache_seq and leaf.ndim >= bdim + 2:
            sdim = bdim + 1
            if leaf.shape[sdim] % tp == 0 and leaf.shape[sdim] >= 2 * tp:
                spec[sdim] = recipe.tp_axis
        return P(*spec)

    return jax.tree.map(visit, cache_abstract)


def to_named(spec_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# engine carry specs — the live training entry point (repro.api.spmd_engine)
# ---------------------------------------------------------------------------

_SEG_KEY_RE = re.compile(r"seg\d+$")


def _path_keys(path) -> Tuple[str, ...]:
    """The string keys along a tree path (dict keys + dataclass attrs;
    sequence indices are skipped)."""
    out = []
    for p in path:
        k = getattr(p, "key", None)
        if k is None:
            k = getattr(p, "name", None)
        if isinstance(k, str):
            out.append(k)
    return tuple(out)


def _run_prefix(path) -> Optional[Tuple]:
    """For a leaf inside a backbone run tree, the path prefix identifying
    its run: ``.../segments/[si]/[ri]`` (client layout) or
    ``.../seg{si}/[ri]`` (server layout); ``None`` elsewhere.  Leaves
    sharing a prefix belong to one run and share layer-stackedness."""
    for i, p in enumerate(path):
        k = getattr(p, "key", None)
        if k == "segments" and i + 2 < len(path):
            return tuple(path[:i + 3])
        if isinstance(k, str) and _SEG_KEY_RE.match(k) and i + 1 < len(path):
            return tuple(path[:i + 2])
    return None


def _stacked_run_group(leaves) -> bool:
    """A stacked run (lane dim already dropped by the caller): every leaf
    shares the leading layer-stack dim L and norm scales — 1-D in a single
    block — are 2-D (the same test as :func:`_is_stacked`)."""
    if not leaves:
        return False
    return (min(l.ndim for l in leaves) >= 2
            and len({l.shape[0] for l in leaves}) == 1)


def train_state_specs(recipe: ShardingRecipe, mesh, carry: Any,
                      *, num_experts: int = -1):
    """PartitionSpec tree for a cohort-stacked engine carry.

    ``carry`` is the fused/spmd engines' scan carry — ``{li: (client,
    client_opt, server, server_opt)}`` with every leaf carrying a leading
    cohort-lane dim (``jax.eval_shape`` output is fine; see
    ``repro.api.spmd_engine.abstract_cohort_carry``).  Per leaf:

      * the lane dim shards over the mesh's ``"lanes"`` axis when the
        cohort's lane count divides it (``recipe.shard_lanes``);
      * remaining dims get the recipe's TP/FSDP/expert rules (the same
        ``_leaf_spec`` the offline dry-run uses), with backbone stacked-run
        layer dims never sharded;
      * leaves below ``recipe.min_shard_elems`` per lane (and all 1-D
        params — Adam ``step`` counters, biases, norm scales) keep only
        the lane spec;
      * Adam moments mirror their params exactly: ``AdamState.m``/``.v``
        share the param tree's structure, shapes, and leaf names, so the
        same rules emit identical specs (asserted by
        tests/test_configs_conformance.py).

    Returns a PartitionSpec tree shaped like ``carry`` — apply
    :func:`to_named` for device placement.
    """
    sizes = axis_sizes(mesh)
    lane_sz = sizes.get(LANE_AXIS, 1) if recipe.shard_lanes else 1

    flat, treedef = jax.tree_util.tree_flatten_with_path(carry)
    groups: Dict[Tuple, list] = {}
    for path, leaf in flat:
        rp = _run_prefix(path)
        if rp is not None:
            groups.setdefault(rp, []).append(leaf)
    # drop the lane dim before the stacked-run test: group leaves are
    # [lanes, (L,) ...]
    stacked = {rp: _stacked_run_group(
                   [jax.ShapeDtypeStruct(l.shape[1:], l.dtype)
                    for l in leaves])
               for rp, leaves in groups.items()}

    def spec_for(path, leaf):
        if leaf.ndim == 0:
            return P()
        lane = (LANE_AXIS if lane_sz > 1 and leaf.shape[0] % lane_sz == 0
                else None)
        per_lane = leaf.size // max(1, leaf.shape[0])
        if per_lane < recipe.min_shard_elems or leaf.ndim < 2:
            return P(lane) if lane else P()
        rp = _run_prefix(path)
        skip = (0, 1) if (rp is not None and stacked[rp]) else (0,)
        keys = _path_keys(path)
        name = keys[-1] if keys else ""
        is_expert = (num_experts > 1 and leaf.ndim > len(skip) + 1
                     and leaf.shape[len(skip)] == num_experts
                     and any(k in ("w_gate", "w_up", "w_down")
                             for k in keys))
        inner = _leaf_spec(leaf, sizes, recipe, False, is_expert,
                           num_experts, name=name, skip_dims=skip)
        spec = list(inner) + [None] * (leaf.ndim - len(inner))
        spec[0] = lane
        return P(*spec)

    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(path, leaf) for path, leaf in flat])


def serve_state_specs(recipe: ShardingRecipe, mesh, params_abstract: Any,
                      cache_abstract: Any, cfg: ModelConfig
                      ) -> Dict[str, Any]:
    """PartitionSpec trees for a serving session's carry: ``{"params": ...,
    "cache": ...}``.

    The sibling of :func:`train_state_specs` for inference
    (``repro.api.serve_session.ServeSession``): the full-network parameter
    tree gets the recipe's TP/FSDP/expert rules via :func:`param_specs`
    (one rule set for training and serving — a recipe tuned in the §Perf
    loop carries over unchanged), and the slot-paged decode cache gets
    :func:`cache_specs` — its leading slot dim is the cache batch dim, so
    decode slots spread over the batch axes and the KV window over the TP
    axis exactly like a training-time decode cache."""
    return {"params": param_specs(params_abstract, cfg, mesh, recipe),
            "cache": cache_specs(cache_abstract, cfg, mesh, recipe)}


def stage_batch_spec(recipe: ShardingRecipe, mesh, lane_count: int,
                     batch: int) -> P:
    """Spec for one cohort's pre-staged ``[rounds, local_epochs, E, B, ...]``
    minibatch tensor: the lane dim over ``"lanes"`` and the per-lane batch
    dim over the mesh's batch axes, each when divisible (trailing feature
    dims replicate)."""
    sizes = axis_sizes(mesh)
    axes = batch_axes(mesh)
    dp = int(np.prod([sizes[a] for a in axes])) if axes else 1
    lane_sz = sizes.get(LANE_AXIS, 1) if recipe.shard_lanes else 1
    lane = LANE_AXIS if lane_sz > 1 and lane_count % lane_sz == 0 else None
    if dp > 1 and batch % dp == 0:
        b_ax = axes if len(axes) > 1 else axes[0]
    else:
        b_ax = None
    return P(None, None, lane, b_ax)
