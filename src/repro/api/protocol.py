"""The ``SplitModel`` protocol — the adapter contract between models and
training engines.

``ResNetSplitModel`` and ``MLPSplitModel`` (core/splitee.py) satisfied this
interface by duck typing; the protocol makes the contract explicit and
checkable.  Any object implementing it can be trained by every registered
engine (api/engines.py) through :class:`repro.api.TrainSession`.

Pytree conventions the engines rely on (see docs/API.md):

  * ``make_client(li)``/``make_server(li)`` return ``{"trainable": ...,
    "state": ...}`` dicts; ``trainable`` holds everything the optimizer
    updates, ``state`` carries non-differentiated statistics (BatchNorm
    running stats; ``{}`` if none).
  * Server trainables are keyed ``layer{l}``/``head`` so Eq. (1)
    cross-layer aggregation matches layers by name across heterogeneous
    split depths.
  * All clients/servers sharing a split layer ``l_i`` must have identical
    pytree structure (same init seed per the paper §III-B), so cohorts can
    be stacked along a lane axis for the fused engine.

Optional training-loss hooks (duck-typed, every engine honors them through
``core.strategies.client_loss_fn`` / ``server_loss_fn``):

  * ``client_loss(trainable, state, x, y) -> (loss, (h, new_state))``
  * ``server_loss(trainable, state, h, li, y) -> (loss, new_state)``

Adapters define them to train on more than the protocol's default
cross-entropy — ``BackboneSplitModel`` routes each side's MoE
load-balancing aux loss this way.  Evaluation always uses the plain
forwards, so aux terms never contaminate accuracy metrics.
"""
from __future__ import annotations

from typing import Any, Dict, Protocol, Sequence, Tuple, runtime_checkable


@runtime_checkable
class SplitModel(Protocol):
    """Adapter splitting a layered network at a per-client cut layer."""

    @property
    def num_layers(self) -> int:
        """Depth L of the full network; valid cut layers are 1..L-1."""
        ...

    def make_client(self, li: int) -> Dict[str, Any]:
        """Client-side net for cut layer ``li``: layers 1..li + exit head."""
        ...

    def make_server(self, li: int) -> Dict[str, Any]:
        """Server-side net for cut layer ``li``: layers li+1..L + head."""
        ...

    def client_forward(self, trainable: Any, state: Any, x: Any, train: bool
                       ) -> Tuple[Any, Any, Any]:
        """``(h, client_logits, new_state)`` — features at the cut plus the
        early-exit logits."""
        ...

    def server_forward(self, trainable: Any, state: Any, h: Any, li: int,
                       train: bool) -> Tuple[Any, Any]:
        """``(server_logits, new_state)`` from transmitted features ``h``."""
        ...

    def stack_clients(self, trees: Sequence[Any]) -> Any:
        """Stack same-structure per-client pytrees along a lane axis."""
        ...

    def unstack(self, stacked: Any, n: int) -> list:
        """Inverse of :meth:`stack_clients`."""
        ...


_REQUIRED_METHODS = ("make_client", "make_server", "client_forward",
                     "server_forward", "stack_clients", "unstack")


def assert_split_model(model: Any) -> None:
    """Raise ``TypeError`` with a precise message if ``model`` does not
    structurally conform to :class:`SplitModel`.  Called by
    ``TrainSession`` at construction so misconfigured adapters fail at the
    facade boundary, not deep inside a jitted step."""
    missing = [m for m in _REQUIRED_METHODS
               if not callable(getattr(model, m, None))]
    if not hasattr(model, "num_layers"):
        missing.append("num_layers")
    if missing or not isinstance(model, SplitModel):
        what = f"missing or non-callable: {missing}" if missing else \
            "see repro.api.protocol.SplitModel"
        raise TypeError(f"{type(model).__name__} does not implement the "
                        f"SplitModel protocol ({what})")
