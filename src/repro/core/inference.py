"""Algorithm 3: entropy-gated adaptive client/server inference.

The paper writes confidence C = -H and sweeps tau in [0, 4] with "larger tau
=> more conservative"; since C <= 0 < tau that literal predicate never fires.
We implement the only consistent reading — **exit iff H < tau_H** — and the
Fig.-2 benchmark reports the paper's conservativeness axis as
``tau_paper = H_CAP - tau_H`` (see docs/DESIGN.md §1).

``AdaptiveInferenceEngine`` is the host-side router used by the serving
example: it runs the client sub-network, gates each request on exit-head
entropy, and forwards only the below-confidence features ``h_i`` to the
server model — realizing the communication savings the jit'd SPMD
``serve_step`` (which must compute both branches) cannot.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.losses import softmax_entropy

H_CAP = 4.0     # the paper's sweep upper bound (~ln(55))


def exit_decision(logits: jnp.ndarray, tau: float) -> jnp.ndarray:
    """True where the early-exit prediction is confident enough (H < tau)."""
    return softmax_entropy(logits) < tau


def paper_tau_to_entropy(tau_paper: float) -> float:
    """Map the paper's conservativeness knob to an entropy threshold."""
    return H_CAP - tau_paper


@dataclass
class AdaptiveStats:
    total: int = 0
    exited: int = 0
    entropy_sum: float = 0.0

    @property
    def client_ratio(self) -> float:
        return self.exited / max(1, self.total)

    @property
    def mean_entropy(self) -> float:
        return self.entropy_sum / max(1, self.total)


class AdaptiveInferenceEngine:
    """Routes a batch of requests through client-side inference and offloads
    the low-confidence remainder to the server (with padding to a bucket size
    so the server step keeps a static shape)."""

    def __init__(self, client_fn: Callable, server_fn: Callable, tau: float,
                 pad_bucket: int = 8):
        self.client_fn = client_fn            # x -> (h, exit_logits)
        self.server_fn = server_fn            # h -> logits
        self.tau = tau
        self.pad_bucket = pad_bucket
        self.stats = AdaptiveStats()

    def __call__(self, x: np.ndarray) -> np.ndarray:
        h, exit_logits = self.client_fn(x)
        H = np.asarray(softmax_entropy(exit_logits))
        exit_mask = H < self.tau
        preds = np.asarray(jnp.argmax(exit_logits, -1)).copy()

        idx = np.nonzero(~exit_mask)[0]
        if len(idx):
            # pad the offloaded sub-batch to a bucket multiple (static shapes)
            n = len(idx)
            padded = int(np.ceil(n / self.pad_bucket) * self.pad_bucket)
            sel = np.concatenate([idx, np.repeat(idx[-1:], padded - n)])
            server_logits = np.asarray(self.server_fn(
                jnp.asarray(np.asarray(h)[sel])))[:n]
            preds[idx] = np.argmax(server_logits, -1)

        self.stats.total += len(x)
        self.stats.exited += int(exit_mask.sum())
        self.stats.entropy_sum += float(H.sum())
        return preds
