"""Hetero-SplitEE core semantics: Eq. (1) aggregation, the two strategies,
and the paper's structural guarantees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import HeteroProfile, OptimizerConfig, SplitEEConfig
from repro.core.aggregation import (cross_layer_aggregate,
                                    participation_counts)
from repro.api import TrainSession
from repro.core.splitee import MLPSplitModel


def _blob_data(n, d, classes, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(classes, d)) * 2.0
    y = rng.integers(0, classes, n).astype(np.int32)
    x = (centers[y] + rng.normal(size=(n, d))).astype(np.float32)
    return x, y


def _trainer(strategy, splits=(1, 2, 3), rounds=0, **kw):
    x, y = _blob_data(600, 16, 3)
    model = MLPSplitModel(in_dim=16, hidden=32, num_classes=3, num_layers=4,
                          seed=0)
    parts = [(x[i::3], y[i::3]) for i in range(3)]
    tr = TrainSession.from_config(model,
                                  SplitEEConfig(profile=HeteroProfile(splits),
                                                strategy=strategy, **kw),
                                  OptimizerConfig(lr=3e-3, total_steps=50),
                                  parts, batch_size=64, engine="reference")
    if rounds:
        tr.train(rounds)
    return tr, (x, y)


# ---------------------------------------------------------------------------
# Eq. (1)
# ---------------------------------------------------------------------------


def test_cross_layer_aggregate_matches_manual():
    rng = np.random.default_rng(0)
    # 3 clients with splits 1,2,3 over a 4-layer net: server models contain
    # layers {2,3,4}, {3,4}, {4} + head
    def mk(keys):
        return {k: {"w": jnp.array(rng.normal(size=(2, 2)), jnp.float32)}
                for k in keys}
    s1 = mk(["layer2", "layer3", "layer4", "head"])
    s2 = mk(["layer3", "layer4", "head"])
    s3 = mk(["layer4", "head"])
    out = cross_layer_aggregate([s1, s2, s3], [1, 2, 3])

    # layer2: only client 1 -> unchanged
    np.testing.assert_array_equal(out[0]["layer2"]["w"], s1["layer2"]["w"])
    # layer3: mean of clients 1,2
    m3 = (s1["layer3"]["w"] + s2["layer3"]["w"]) / 2
    np.testing.assert_allclose(out[0]["layer3"]["w"], m3, atol=1e-6)
    np.testing.assert_allclose(out[1]["layer3"]["w"], m3, atol=1e-6)
    # layer4 + head: mean of all three, broadcast back to every member
    for key in ("layer4", "head"):
        m = (s1[key]["w"] + s2[key]["w"] + s3[key]["w"]) / 3
        for i in range(3):
            np.testing.assert_allclose(out[i][key]["w"], m, atol=1e-6)


def test_aggregate_permutation_invariant():
    rng = np.random.default_rng(1)
    models = [{"layer3": {"w": jnp.array(rng.normal(size=(3,)), jnp.float32)},
               "head": {"w": jnp.array(rng.normal(size=(3,)), jnp.float32)}}
              for _ in range(4)]
    a = cross_layer_aggregate(models, [2, 2, 2, 2])
    perm = [2, 0, 3, 1]
    b = cross_layer_aggregate([models[i] for i in perm],
                              [2, 2, 2, 2])
    np.testing.assert_allclose(a[0]["layer3"]["w"], b[0]["layer3"]["w"],
                               atol=1e-6)


def test_participation_counts():
    nc, ns = participation_counts([1, 2, 2, 3], num_layers=4)
    assert nc == [4, 3, 1, 0]       # layer0 client-side for all, etc.
    assert ns == [0, 1, 3, 4]


def test_participation_boundary():
    """C_l over 0-indexed layers is {i : l_i <= l}: a client whose cut sits
    exactly at the queried layer participates (its server-side model starts
    at layer l_i), one layer earlier it does not."""
    p = HeteroProfile((2, 3))
    assert p.participation(1) == ()
    assert p.participation(2) == (0,)       # l_i == layer -> server-side
    assert p.participation(3) == (0, 1)
    assert p.participation(5) == (0, 1)
    # consistent with the aggregation-count oracle at every layer
    for layer in range(4):
        _, ns = participation_counts([2, 3], num_layers=4)
        assert len(p.participation(layer)) == ns[layer]


# ---------------------------------------------------------------------------
# strategies (Alg. 1 / Alg. 2 structure)
# ---------------------------------------------------------------------------


def test_sequential_shares_one_server():
    tr, _ = _trainer("sequential")
    assert len(tr.state.servers) == 1
    assert tr.ctx.server_lr_div == 3.0              # lr / N (paper Table II)


def test_sequential_server_steps_per_round():
    tr, _ = _trainer("sequential")
    tr.train(1, local_epochs=2)
    # shared server updated N x E = 3 x 2 = 6 times
    assert int(tr.state.server_opts[0].step) == 6
    # each client updated E = 2 times
    assert all(int(o.step) == 2 for o in tr.state.client_opts)


def test_averaging_syncs_common_layers():
    tr, _ = _trainer("averaging", rounds=2)
    # after aggregation the deepest common layer (layer4, head) is identical
    for key in ("layer4", "head"):
        w0 = tr.state.servers[0]["trainable"][key]["w"]
        for s in tr.state.servers[1:]:
            np.testing.assert_allclose(w0, s["trainable"][key]["w"], atol=1e-6)
    # layer2 exists only in client-0's server model
    assert "layer2" in tr.state.servers[0]["trainable"]
    assert "layer2" not in tr.state.servers[2]["trainable"]


def test_distributed_does_not_sync():
    tr, _ = _trainer("distributed", splits=(2, 2, 2), rounds=2)
    w = [np.asarray(s["trainable"]["head"]["w"]) for s in tr.state.servers]
    assert not np.allclose(w[0], w[1])          # independent training drifts


def test_same_seed_init_property():
    """Paper: all models initialized from the same random seed — common
    layers start identical across clients."""
    model = MLPSplitModel(in_dim=8, hidden=16, num_classes=3, num_layers=4)
    s1 = model.make_server(1)["trainable"]
    s3 = model.make_server(3)["trainable"]
    np.testing.assert_array_equal(s1["layer4"]["w"], s3["layer4"]["w"])
    c1 = model.make_client(2)["trainable"]
    c2 = model.make_client(3)["trainable"]
    np.testing.assert_array_equal(c1["layers"]["layer2"]["w"],
                                  c2["layers"]["layer2"]["w"])


def test_training_learns_and_adaptive_inference():
    tr, (x, y) = _trainer("averaging", rounds=25)
    ev = tr.evaluate(x[:300], y[:300], batch_size=100)
    assert min(ev["client_acc"]) > 0.8
    assert min(ev["server_acc"]) > 0.8
    # threshold monotonicity: higher tau_H -> more client exits
    lo = tr.evaluate_adaptive(x[:300], y[:300], tau=0.05, batch_size=100)
    hi = tr.evaluate_adaptive(x[:300], y[:300], tau=1.0, batch_size=100)
    assert all(h >= l for h, l in zip(hi["client_ratio"], lo["client_ratio"]))
