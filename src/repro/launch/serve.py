"""Adaptive serving CLI: a thin front-end over ``repro.api.ServeSession``.

Serves a stream of synthetic prompts through the continuous-batching
entropy-gated engine (Alg. 3): requests join fixed decode slots, each
decode tick gates at the client boundary's exit head, and the report gives
the client adoption ratio plus the server-offload compute saving — the
quantities the paper's Fig. 2 trades against accuracy.

``--boundary`` selects which exit boundary acts as the client cut.  The
gate head, the split profile, and the reported cut layer are all derived
from the one sorted source (``repro.api.serve_session.
resolve_serve_boundary``) so they cannot disagree, whatever order the
config lists its ``exit_layers`` in (tests/test_serve_boundary.py).

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --tau 2.0
  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b \
      --ckpt ckpt/run1/ckpt-00000100          # serve trained weights
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs as configs_mod
from repro.api.serve_session import ServeSession, resolve_serve_boundary
from repro.models.backbone import init_backbone


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-tokens", type=int, default=32)
    ap.add_argument("--tau", type=float, default=2.0)
    ap.add_argument("--boundary", type=int, default=0,
                    help="exit boundary index used as the client cut "
                         "(indexes sorted(exit_layers))")
    ap.add_argument("--exit-policy", default="select",
                    choices=["select", "sticky"])
    ap.add_argument("--ckpt", default=None,
                    help="TrainSession checkpoint stem to serve; default "
                         "serves seed-initialized weights")
    ap.add_argument("--kernels", default="auto",
                    choices=["auto", "pallas", "ref"],
                    help="kernel backend for the routed hot sites: "
                         "auto = pallas on TPU, ref elsewhere")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs_mod.get(args.arch).smoke().with_(kernels=args.kernels)
    exits, cut, skip_frac = resolve_serve_boundary(cfg, args.boundary)
    max_len = args.prompt_len + 1 + args.decode_tokens

    if args.ckpt:
        from repro.core.backbone_splitee import BackboneSplitModel
        session = ServeSession.restore(
            args.ckpt, BackboneSplitModel(cfg, seed=args.seed),
            tau=args.tau, boundary=args.boundary, slots=args.slots,
            max_len=max_len, exit_policy=args.exit_policy)
    else:
        params = init_backbone(jax.random.PRNGKey(args.seed), cfg)
        session = ServeSession(cfg, params, tau=args.tau,
                               boundary=args.boundary, slots=args.slots,
                               max_len=max_len,
                               exit_policy=args.exit_policy)

    rng = np.random.default_rng(1)
    for _ in range(args.requests):
        session.submit(rng.integers(0, cfg.vocab_size, args.prompt_len),
                       decode_tokens=args.decode_tokens)
    session.run()

    st = session.stats
    ratio = st.adoption_ratio
    print(f"arch={cfg.name} tau={args.tau} boundary={args.boundary} "
          f"(cut layer {cut}/{cfg.num_layers}) policy={args.exit_policy}")
    print(f"served {st.requests} requests / {st.tokens} decode tokens in "
          f"{st.decode_ticks} ticks ({st.wall_s:.2f}s, "
          f"{st.tokens / max(st.wall_s, 1e-9):.1f} tok/s)  "
          f"client adoption ratio {ratio:.3f}")
    print(f"server compute skipped ~{ratio * skip_frac * 100:.1f}% of layer "
          f"work (exited tokens skip {skip_frac * 100:.0f}% of layers)")
    if args.exit_policy == "sticky":
        print(f"client-only ticks: {st.client_only_ticks}/{st.decode_ticks}")


if __name__ == "__main__":
    main()
