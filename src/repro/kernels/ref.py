"""Pure-jnp oracles for every Pallas kernel (the test-suite ground truth)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        kv_valid=None) -> jnp.ndarray:
    """q: (B,H,Tq,D), k/v: (B,Hkv,Tk,D) -> (B,H,Tq,D), fp32 softmax.
    ``kv_valid`` (traced int32 scalar) masks keys at ``kpos >= kv_valid`` —
    the decode ring-buffer valid prefix."""
    B, H, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, Tq, D).astype(jnp.float32)
    s = jnp.einsum("bhgtd,bhsd->bhgts", qg, k.astype(jnp.float32))
    s = s / math.sqrt(D)
    qpos = jnp.arange(Tq)[:, None]
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    if kv_valid is not None:
        mask &= kpos < kv_valid
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgts,bhsd->bhgtd", p, v.astype(jnp.float32))
    return out.reshape(B, H, Tq, D).astype(q.dtype)


def entropy_exit_ref(logits, tau: float):
    """(B, V) -> (entropy (B,), exit (B,) int32)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    H = -jnp.sum(jnp.exp(logp) * logp, axis=-1)
    return H, (H < tau).astype(jnp.int32)


def rwkv_wkv_ref(r, k, v, log_w, u):
    """Naive token-by-token recurrence.  r/k/v/log_w: (BH, T, K), u: (BH, K).
    Returns y (BH, T, K) fp32."""
    y, _ = rwkv_wkv_ref_state(r, k, v, log_w, u)
    return y


def rwkv_wkv_ref_state(r, k, v, log_w, u):
    """:func:`rwkv_wkv_ref` plus the final carried state (BH, K, K) fp32."""
    BH, T, K = r.shape
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    wf = jnp.exp(log_w.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bk,bv->bkv", kt, vt)
        y = jnp.einsum("bk,bkv->bv", rt, S + uf[..., None] * kv)
        S = S * wt[..., None] + kv
        return S, y

    S0 = jnp.zeros((BH, K, K), jnp.float32)
    ST, ys = jax.lax.scan(step, S0, (jnp.moveaxis(rf, 1, 0),
                                     jnp.moveaxis(kf, 1, 0),
                                     jnp.moveaxis(vf, 1, 0),
                                     jnp.moveaxis(wf, 1, 0)))
    return jnp.moveaxis(ys, 0, 1), ST


def rwkv_wkv_ref_model(r, k, v, log_w, u):
    """Model-layout oracle: r/k/v/log_w (B, T, H, K), u (H, K) ->
    ``(y (B, T, H, K) fp32, S_T (B, H, K, K) fp32)`` — the exact contract of
    ``dispatch.KernelBackend.wkv``; the pallas backend recomputes through
    this function in its backward pass."""
    B, T, H, K = r.shape

    def flat(x):
        return jnp.moveaxis(x, 2, 1).reshape(B * H, T, K)

    uf = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, K)
    y, ST = rwkv_wkv_ref_state(flat(r), flat(k), flat(v), flat(log_w), uf)
    y = jnp.moveaxis(y.reshape(B, H, T, K), 1, 2)
    return y, ST.reshape(B, H, K, K)
