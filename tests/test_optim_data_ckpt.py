"""Optimizer, data pipeline and checkpoint substrates."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.config import OptimizerConfig
from repro.data.synthetic import SyntheticImageDataset, SyntheticLMDataset
from repro.optim import adam_init, adam_update


def test_adam_minimizes_quadratic():
    cfg = OptimizerConfig(lr=0.1, total_steps=100)
    params = {"x": jnp.array([5.0, -3.0])}
    opt = adam_init(params, cfg)
    loss = lambda p: jnp.sum(jnp.square(p["x"] - jnp.array([1.0, 2.0])))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt = adam_update(params, g, opt, cfg, jnp.float32(0.1))
    np.testing.assert_allclose(np.asarray(params["x"]), [1.0, 2.0], atol=1e-2)
    assert int(opt.step) == 200


def test_adam_lr_scale_tree():
    """The Sequential strategy's server-LR divisor via per-leaf scaling."""
    cfg = OptimizerConfig(lr=0.1)
    params = {"a": jnp.array([1.0]), "b": jnp.array([1.0])}
    opt = adam_init(params, cfg)
    g = {"a": jnp.array([1.0]), "b": jnp.array([1.0])}
    scales = {"a": 1.0, "b": 0.0}
    new, _ = adam_update(params, g, opt, cfg, jnp.float32(0.1),
                         lr_scale_tree=scales)
    assert float(new["a"][0]) != 1.0
    assert float(new["b"][0]) == 1.0           # zero-scaled leaf frozen


def test_adam_bf16_state():
    cfg = OptimizerConfig(state_dtype=jnp.bfloat16)
    params = {"x": jnp.zeros((4,), jnp.bfloat16)}
    opt = adam_init(params, cfg)
    assert opt.m["x"].dtype == jnp.bfloat16
    new, opt2 = adam_update(params, {"x": jnp.ones((4,), jnp.bfloat16)}, opt,
                            cfg, jnp.float32(1e-2))
    assert new["x"].dtype == jnp.bfloat16
    assert opt2.v["x"].dtype == jnp.bfloat16


def test_synthetic_image_difficulty_ordering():
    """More classes => lower linear-probe separability (the CIFAR-10 vs -100
    difficulty proxy the paper's claims rely on)."""
    def probe_acc(classes):
        ds = SyntheticImageDataset(num_classes=classes, train_size=2000,
                                   test_size=500, seed=1, noise=8.0)
        x, y = ds.train
        xt, yt = ds.test
        # nearest-class-mean probe
        means = np.stack([x[y == c].mean(0) for c in range(classes)])
        d = ((xt[:, None] - means[None]) ** 2).reshape(len(xt), classes, -1).sum(-1)
        return float((d.argmin(1) == yt).mean())

    a10, a100 = probe_acc(10), probe_acc(100)
    assert a10 > a100 + 0.2
    assert a10 > 0.5                            # learnable at all


def test_synthetic_augment_shapes():
    ds = SyntheticImageDataset(num_classes=10, train_size=64, test_size=16)
    rng = np.random.default_rng(0)
    out = SyntheticImageDataset.augment(rng, ds.train[0][:8])
    assert out.shape == (8, 32, 32, 3)


def test_synthetic_lm_structure():
    ds = SyntheticLMDataset(vocab_size=101, seq_len=32, structure=1.0)
    toks, labels = next(ds.batches(4, 1))
    assert toks.shape == (4, 32) and labels.shape == (4, 32)
    # with structure=1.0 the affine rule holds everywhere
    assert np.array_equal(toks[:, 1:], labels[:, :-1])


def test_checkpoint_roundtrip():
    tree = {"a": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "b": [jnp.ones((4,), jnp.bfloat16), jnp.array(3, jnp.int32)]}
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ckpt")
        save_pytree(path, tree, metadata={"step": 7})
        like = jax.tree.map(jnp.zeros_like, tree)
        back = load_pytree(path, like)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
