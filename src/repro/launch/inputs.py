"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, and allocation-free.  The dry-run lowers against these."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import HeteroProfile, ModelConfig, ShapeConfig
from repro.models import frontend as fe
from repro.models.backbone import build_plan, init_cache


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Inputs of the fused Hetero-SplitEE train (or prefill) step."""
    B, T = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {"split_ids": _sds((B,), jnp.int32)}
    if cfg.arch_type == "audio":
        # stubbed encoder states (frontend carve-out) + decoder tokens
        specs["enc"] = _sds((B, min(T, cfg.cross_source_len),
                             fe.WHISPER_FRAME_DIM), cfg.dtype)
        specs["tokens"] = _sds((B, T), jnp.int32)
        specs["labels"] = _sds((B, T), jnp.int32)
    elif cfg.arch_type == "vlm":
        P = fe.NUM_VISION_PATCHES
        t = max(T - P, 1)
        specs["embeds"] = _sds((B, P, fe.SIGLIP_PATCH_DIM), cfg.dtype)
        specs["tokens"] = _sds((B, t), jnp.int32)
        specs["labels"] = _sds((B, P + t), jnp.int32)
    else:
        specs["tokens"] = _sds((B, T), jnp.int32)
        specs["labels"] = _sds((B, T), jnp.int32)
    return specs


def serve_input_specs(cfg: ModelConfig, shape: ShapeConfig
                      ) -> Dict[str, Any]:
    """Inputs of the one-token decode step: single new token + a cache of
    ``seq_len`` context (ring-buffer-bounded when cfg.sliding_window)."""
    B, S = shape.global_batch, shape.seq_len
    cache_shapes = jax.eval_shape(
        lambda: init_cache(cfg, B, S, cfg.dtype))
    specs: Dict[str, Any] = {
        "tokens": _sds((B, 1), jnp.int32),
        "cache": cache_shapes,
        "cache_len": _sds((), jnp.int32),
    }
    if cfg.arch_type == "audio":
        specs["enc"] = _sds((B, cfg.cross_source_len, fe.WHISPER_FRAME_DIM),
                            cfg.dtype)
    # vlm decode: prefix patches already live in the cache; tokens only.
    return specs


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocating (jax.eval_shape)."""
    from repro.models.backbone import init_backbone
    return jax.eval_shape(lambda k: init_backbone(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))
