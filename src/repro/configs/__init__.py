"""Architecture registry: the 10 assigned architectures + the paper's own
ResNet-18.  Each module exposes ``config()`` (the exact assigned full config),
``smoke()`` (a reduced same-family variant: <=4 layers, d_model<=512,
<=4 experts) and ``profile()`` (the default Hetero-SplitEE client profile)."""
from __future__ import annotations

import importlib
from typing import Dict

ARCH_IDS = (
    "phi3_medium_14b",
    "minitron_8b",
    "zamba2_1p2b",
    "whisper_small",
    "command_r_35b",
    "deepseek_v3_671b",
    "glm4_9b",
    "qwen3_moe_235b_a22b",
    "paligemma_3b",
    "rwkv6_3b",
)

# CLI ids use dashes, matching the assignment table.
CANONICAL = {a.replace("_", "-").replace("-1p2b", "-1.2b"): a for a in ARCH_IDS}


def get(arch: str):
    """Resolve an architecture id (dash or underscore form) to its module.

    Raises ``ValueError`` for an id that names no module — and only then:
    a *registered* module failing to import (a broken dependency inside
    it) propagates its real error instead of being misreported as an
    unknown architecture."""
    name = CANONICAL.get(arch, arch).replace("-", "_").replace("1.2b", "1p2b")
    try:
        return importlib.import_module(f"repro.configs.{name}")
    except ModuleNotFoundError as e:
        if e.name == f"repro.configs.{name}":
            raise ValueError(
                f"{arch!r} is not a registered architecture; known: "
                f"{', '.join(all_arch_ids())}") from None
        raise


def all_arch_ids():
    return list(CANONICAL.keys())
