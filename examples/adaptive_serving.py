"""Adaptive client/server serving (paper Alg. 3 + §IV-D) with batched
requests: the host-side router runs client inference, exits the confident
requests locally and ships only the rest to the server model — realizing the
communication saving the paper trades via the threshold tau.

  PYTHONPATH=src python examples/adaptive_serving.py
"""
import numpy as np

from repro.api import TrainSession
from repro.config import HeteroProfile, OptimizerConfig, SplitEEConfig
from repro.core.inference import AdaptiveInferenceEngine
from repro.core.splitee import MLPSplitModel
from repro.data.pipeline import ClientPartitioner


def main():
    rng = np.random.default_rng(1)
    n, d, classes = 4000, 32, 10
    centers = rng.normal(size=(classes, d)) * 1.2
    y = rng.integers(0, classes, n).astype(np.int32)
    x = (centers[y] + rng.normal(size=(n, d))).astype(np.float32)
    train, test = (x[:3200], y[:3200]), (x[3200:], y[3200:])

    model = MLPSplitModel(in_dim=d, hidden=64, num_classes=classes,
                          num_layers=4, seed=0)
    profile = HeteroProfile(split_layers=(2, 2, 2))
    session = TrainSession.from_config(
        model, SplitEEConfig(profile=profile, strategy="averaging"),
        OptimizerConfig(lr=3e-3, total_steps=50),
        ClientPartitioner(3, seed=0).split(*train), batch_size=64)
    session.train(rounds=40)

    # wire client 0 + its server replica into the request router: the
    # TrainState pytree is the single source of every trained tensor
    li = profile.split_layers[0]
    client = session.state.clients[0]
    server = session.state.servers[0]

    def client_fn(xb):
        h, logits, _ = model.client_forward(client["trainable"],
                                            client["state"], xb, train=False)
        return h, logits

    def server_fn(h):
        logits, _ = model.server_forward(server["trainable"], server["state"],
                                         h, li, train=False)
        return logits

    print(f"{'tau':>5s} {'acc':>7s} {'client%':>8s} {'offloaded':>10s}")
    for tau in (0.05, 0.2, 0.5, 1.0, 2.0):
        engine = AdaptiveInferenceEngine(client_fn, server_fn, tau=tau)
        preds = []
        for i in range(0, len(test[0]), 64):
            preds.append(engine(test[0][i : i + 64]))
        acc = float((np.concatenate(preds) == test[1][: len(
            np.concatenate(preds))]).mean())
        st = engine.stats
        print(f"{tau:5.2f} {acc:7.3f} {st.client_ratio:8.2%} "
              f"{st.total - st.exited:10d}")


if __name__ == "__main__":
    main()
