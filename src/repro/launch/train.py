"""Training driver: THE training entry point of the repo, built on
``repro.api.TrainSession`` with the mesh-sharded ``"spmd"`` engine.

Every scale runs the same code path:
  * host demo (this container): ``--host-devices 4`` forces fake CPU
    devices before jax initializes, the engine builds the default data
    mesh over them, and the global batch shards across the ``data`` axis —
    actually executes, and is cross-checked against the reference engine
    by tests/test_spmd_engine.py.
  * production: ``--mesh single|multi`` builds the 256/512-chip mesh from
    ``launch.mesh.make_production_mesh`` and hands it to the session
    (``TrainSession(..., mesh=...)``).
  * one device, no mesh: ``--engine auto`` degrades to the fused engine
    and says why (the ``engine_name`` selection note).

Sharding is recipe-driven (``launch/shardings.py``): ``--recipe
{greedy,megatron,hybrid,fsdp-off,replicate}`` picks how parameters and
Adam moments spread over the mesh (FSDP over the data axis by default;
"replicate" is batch-only sharding), and ``--lanes N`` factors a cohort-
lane axis out of the data axis so stacked cohort lanes shard instead of
replicating — e.g. ``--host-devices 4 --lanes 2`` splits each two-client
cohort over two devices and each lane's batch over the other two.

Checkpointing is the session's periodic-save policy: ``--save-every N``
rotates ``ckpt-<round>`` pairs under ``--checkpoint-dir`` (keep-last-k),
and ``--resume`` picks the run back up from the newest valid checkpoint
via ``TrainSession.restore_latest``.

Besides the paper-scale ``--model mlp|resnet`` adapters, ``--arch <name>``
trains any registered ``configs/`` backbone (GLM-4, DeepSeek-V3, Qwen3-MoE,
RWKV6, Whisper, …) through the same session facade: the architecture module
is resolved via ``repro.configs.get(name)``, ``--smoke`` picks its reduced
``smoke()`` variant (the full ``config()`` otherwise), and the model is the
``BackboneSplitModel`` adapter over a synthetic sequence-classification
token stream.  Cut layers must sit at the config's ``exit_layers``
(``--splits`` defaults to cycling them across clients).

Example (4 fake host devices, spmd engine, resumable):
  PYTHONPATH=src python -m repro.launch.train --model mlp --clients 4 \
      --rounds 20 --host-devices 4 --checkpoint-dir /tmp/run \
      --save-every 5 --resume

Example (GLM-4 smoke backbone, fused engine):
  PYTHONPATH=src python -m repro.launch.train --arch glm4_9b --smoke \
      --engine fused --clients 4 --rounds 5 --batch 16 \
      --train-size 256 --test-size 64 --checkpoint-dir /tmp/glm4
"""
from __future__ import annotations

import glob
import os

# must run before jax initializes: fake host devices for the spmd engine,
# and the multi-host XLA flags + coordinator options for --distributed
from repro.launch import distributed as distributed_mod
from repro.launch.hostdevices import force_host_devices

force_host_devices("--host-devices")
_DIST = distributed_mod.setup_from_argv()

import argparse
import json
import time

import jax
import numpy as np

from repro import configs as configs_mod
from repro.api import TrainSession
from repro.config import HeteroProfile, OptimizerConfig, SplitEEConfig
from repro.core.backbone_splitee import BackboneSplitModel
from repro.core.splitee import MLPSplitModel, ResNetSplitModel
from repro.data.pipeline import ClientPartitioner
from repro.data.synthetic import SyntheticImageDataset, SyntheticSeqClsDataset
from repro.launch.mesh import make_lane_host_mesh, make_production_mesh
from repro.launch.shardings import NAMED_RECIPES
from repro.models.resnet import ResNetConfig

#: default hetero cut layers per model family (paper Table I spirit:
#: clients split shallow/mid/deep)
DEFAULT_SPLITS = {"mlp": (1, 2, 3), "resnet": (3, 4, 5)}

#: CLI knobs that shape the regenerated dataset / model / session; a resumed
#: run must match every one of them or it would silently replay a different
#: data stream — or, for ``arch``/``grad_mode``, silently continue a
#: checkpoint into a *different network or gradient math* (driver.json
#: sidecar next to the checkpoints)
DATA_KNOBS = ("model", "arch", "smoke", "seq_len", "clients", "splits",
              "strategy", "aggregate_every", "batch", "grad_mode", "seed",
              "train_size", "test_size")


def driver_knobs(args, splits) -> dict:
    d = {k: getattr(args, k) for k in DATA_KNOBS if k != "splits"}
    d["splits"] = list(splits)
    return d


def check_driver_sidecar(ckpt_dir: str, args, splits) -> None:
    """Fail loudly when a resumed run regenerates its data/model from
    different knobs than the saved one (the session manifest cannot see
    dataset-shaping flags like --train-size — the sidecar can)."""
    path = os.path.join(ckpt_dir, "driver.json")
    if not os.path.exists(path):
        return                      # checkpoints written by library code
    with open(path) as f:
        saved = json.load(f)
    now = driver_knobs(args, splits)
    for k in DATA_KNOBS:
        if k in saved and saved[k] != now[k]:
            raise SystemExit(
                f"--resume mismatch: checkpoint dir was written with "
                f"--{k.replace('_', '-')}={saved[k]!r} but this run has "
                f"{now[k]!r}")


def resolve_arch_config(args):
    """The --arch run's ModelConfig (a cheap dataclass — no parameter
    init yet), or None for the mlp/resnet families."""
    if not args.arch:
        return None
    try:
        mod = configs_mod.get(args.arch)
    except ValueError as e:
        raise SystemExit(f"--arch: {e}") from None
    cfg = mod.smoke() if args.smoke else mod.config()
    return cfg.with_(kernels=getattr(args, "kernels", "auto"))


def build_model_and_data(args, arch_cfg):
    """(SplitModel adapter, train shards, held-out (x, y))."""
    if arch_cfg is not None:
        cfg = arch_cfg
        model = BackboneSplitModel(cfg, seed=args.seed)
        ds = SyntheticSeqClsDataset(
            vocab_size=cfg.vocab_size, seq_len=args.seq_len,
            num_classes=min(8, cfg.vocab_size),
            train_size=args.train_size, test_size=args.test_size,
            seed=args.seed)
        x, y = ds.train
        xt, yt = ds.test
    elif args.model == "mlp":
        rng = np.random.default_rng(args.seed)
        classes, d = 5, 32
        centers = rng.normal(size=(classes, d)) * 2.0
        y = rng.integers(0, classes, args.train_size + args.test_size)
        y = y.astype(np.int32)
        x = (centers[y] + rng.normal(size=(len(y), d))).astype(np.float32)
        xt, yt = x[args.train_size:], y[args.train_size:]
        x, y = x[:args.train_size], y[:args.train_size]
        model = MLPSplitModel(in_dim=d, hidden=64, num_classes=classes,
                              num_layers=6, seed=args.seed)
    else:
        ds = SyntheticImageDataset(num_classes=10,
                                   train_size=args.train_size,
                                   test_size=args.test_size,
                                   image_size=16, noise=2.0, seed=args.seed)
        x, y = ds.train
        xt, yt = ds.test
        model = ResNetSplitModel(ResNetConfig(num_classes=10,
                                              width_mult=0.125,
                                              image_size=16), seed=args.seed)
    parts = ClientPartitioner(args.clients, seed=args.seed).split(x, y)
    return model, parts, (xt, yt)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="mlp", choices=["mlp", "resnet"])
    ap.add_argument("--arch", default="",
                    help="train a configs/ backbone (e.g. glm4_9b, "
                         "qwen3-moe-235b-a22b) through BackboneSplitModel; "
                         "overrides --model")
    ap.add_argument("--smoke", action="store_true",
                    help="with --arch: use the reduced smoke() config "
                         "instead of the full-scale config()")
    ap.add_argument("--seq-len", type=int, default=16,
                    help="with --arch: synthetic token sequence length")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--splits", default="",
                    help="comma-separated cut layer per client (default: "
                         "cycle the model family's depths)")
    ap.add_argument("--strategy", default="averaging",
                    choices=["averaging", "distributed", "sequential"])
    ap.add_argument("--aggregate-every", type=int, default=1)
    ap.add_argument("--rounds", type=int, default=20,
                    help="total rounds the run should reach (a resumed run "
                         "trains only the remainder)")
    ap.add_argument("--local-epochs", type=int, default=1)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "spmd", "fused", "reference"])
    ap.add_argument("--grad-mode", default="eq1", choices=["eq1", "sum"])
    ap.add_argument("--kernels", default="auto",
                    choices=["auto", "pallas", "ref"],
                    help="kernel backend for the routed hot sites "
                         "(attention, wkv, entropy gate) with --arch: "
                         "auto = pallas on TPU, ref elsewhere.  Layout-"
                         "only — equivalence-gated, so not a resume knob")
    ap.add_argument("--mesh", default="auto",
                    choices=["auto", "single", "multi"],
                    help="auto: engine default over visible devices; "
                         "single/multi: the production TPU mesh")
    ap.add_argument("--recipe", default=None,
                    choices=sorted(NAMED_RECIPES),
                    help="spmd sharding recipe (launch/shardings.py): how "
                         "cohort lanes, params and Adam moments spread "
                         "over the mesh; 'replicate' is batch-only "
                         "sharding.  Default: 'greedy' for fresh runs, "
                         "the checkpoint's saved recipe on --resume")
    ap.add_argument("--lanes", type=int, default=1,
                    help="factor a cohort-lane axis of this size out of "
                         "the mesh's data axis (shards stacked cohort "
                         "lanes instead of replicating them)")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N fake CPU devices (consumed pre-import)")
    ap.add_argument("--distributed", action="store_true",
                    help="multi-host run: jax.distributed.initialize "
                         "before training, meshes over the global device "
                         "list (consumed pre-argparse; env fallbacks "
                         "REPRO_DISTRIBUTED/REPRO_COORDINATOR/...)")
    ap.add_argument("--coordinator", default=None,
                    help="coordinator address host:port for --distributed "
                         "(implies it); unset = jax cluster auto-detection")
    ap.add_argument("--num-processes", type=int, default=None,
                    help="total process count for --distributed")
    ap.add_argument("--process-id", type=int, default=None,
                    help="this process's rank for --distributed")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--save-every", type=int, default=0)
    ap.add_argument("--keep-last", type=int, default=3)
    ap.add_argument("--resume", action="store_true",
                    help="continue from the newest valid checkpoint in "
                         "--checkpoint-dir (restores params, Adam moments, "
                         "the round counter, and the data cursors)")
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--train-size", type=int, default=4096)
    ap.add_argument("--test-size", type=int, default=1024)
    ap.add_argument("--tau", type=float, default=0.5,
                    help="entropy threshold for the adaptive eval")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # before any jax computation: join the multi-host cluster so every
    # mesh below spans the global device list
    distributed_mod.maybe_initialize(_DIST)
    coordinator = distributed_mod.is_coordinator()

    arch_cfg = resolve_arch_config(args)
    if args.splits:
        splits = tuple(int(s) for s in args.splits.split(","))
    elif arch_cfg is not None:
        cuts = tuple(sorted(arch_cfg.exit_layers))   # the valid cut layers
        splits = tuple(cuts[i % len(cuts)] for i in range(args.clients))
    else:
        splits = tuple(DEFAULT_SPLITS[args.model][i % 3]
                       for i in range(args.clients))
    if len(splits) != args.clients:
        raise SystemExit(f"--splits names {len(splits)} clients but "
                         f"--clients is {args.clients}")
    if arch_cfg is not None:
        bad = sorted(set(splits) - set(arch_cfg.exit_layers))
        if bad:
            raise SystemExit(
                f"--splits {bad} are not exit boundaries of "
                f"{arch_cfg.name}; valid cut layers: "
                f"{sorted(arch_cfg.exit_layers)}")

    resuming = bool(args.resume and args.checkpoint_dir and glob.glob(
        os.path.join(args.checkpoint_dir, "ckpt-*.json")))
    if resuming:
        # before any (possibly full-scale) parameter init: a knob mismatch
        # must die on the string comparison, not after materializing the
        # model and dataset
        check_driver_sidecar(args.checkpoint_dir, args, splits)

    model, parts, (xt, yt) = build_model_and_data(args, arch_cfg)
    try:
        if args.mesh != "auto":
            mesh = make_production_mesh(multi_pod=args.mesh == "multi",
                                        lanes=args.lanes)
        elif args.lanes > 1:
            mesh = make_lane_host_mesh(args.lanes)
        else:
            mesh = None
    except ValueError as e:
        raise SystemExit(f"--lanes: {e}") from None

    splitee_cfg = SplitEEConfig(profile=HeteroProfile(splits),
                                strategy=args.strategy,
                                aggregate_every=args.aggregate_every,
                                entropy_threshold=args.tau)
    opt_cfg = OptimizerConfig(
        lr=args.lr, warmup_steps=0,
        total_steps=max(args.rounds * args.local_epochs, 1) + 16)

    resumed = False
    if resuming:
        # checkpoints exist, so --resume must resume or die — a failure
        # here (all pairs unreadable, wrong engine for this host, ...)
        # must never silently start a fresh run whose rotation would then
        # delete the real checkpoints
        try:
            session = TrainSession.restore_latest(
                args.checkpoint_dir, model, parts, engine=args.engine,
                mesh=mesh, recipe=args.recipe)
        except Exception as e:                            # noqa: BLE001
            raise SystemExit(
                f"--resume: cannot restore from {args.checkpoint_dir!r}: "
                f"{e}") from e
        resumed = True
        # the restored session replays its own saved config; the CLI data
        # stream is rebuilt from the flags, so a knob mismatch would
        # silently train on different data — fail loudly instead
        for knob, want, have in (
                ("seed", session.ctx.seed, args.seed),
                ("batch", session.ctx.batch_size, args.batch),
                ("grad-mode", session.ctx.grad_mode, args.grad_mode),
                ("strategy", session.ctx.strategy, args.strategy),
                ("splits", tuple(session.ctx.profile.split_layers), splits)):
            if want != have:
                raise SystemExit(
                    f"--resume mismatch: checkpoint was written with "
                    f"{knob}={want!r} but this run has {knob}={have!r}")
    else:
        session = TrainSession.from_config(
            model, splitee_cfg, opt_cfg, parts, batch_size=args.batch,
            engine=args.engine, seed=args.seed, mesh=mesh,
            grad_mode=args.grad_mode, recipe=args.recipe)

    what = (f"arch={args.arch}{' (smoke)' if args.smoke else ''} "
            f"[{model.name}]" if args.arch else f"model={args.model}")
    print(f"{what}  clients={args.clients}  splits={splits}  "
          f"strategy={args.strategy}  grad_mode={args.grad_mode}")
    print(f"devices={len(jax.devices())}"
          + (f" ({jax.process_count()} processes, "
             f"rank {jax.process_index()})"
             if jax.process_count() > 1 else "")
          + f"  engine={session.engine_name}"
          + (f"  recipe={session.ctx.recipe_name}"
             if session.engine.name == "spmd" else "")
          + (f"  [resumed at round {session.round}]" if resumed else ""))

    # checkpoints and sidecars are shared-filesystem side effects: only
    # the coordinator process writes them (every process still restores).
    # Every rank still runs the identical save_every segmentation below —
    # each engine.run() segment dispatches the same jit/collective
    # sequence on every process (chunk plans, the spmd carry fetch), so
    # ranks must not diverge in how the run is cut up; the file write
    # itself is gated on process 0 inside TrainSession._save_rotating.
    ckpt_dir = args.checkpoint_dir
    if ckpt_dir and coordinator:
        os.makedirs(ckpt_dir, exist_ok=True)
        with open(os.path.join(ckpt_dir, "driver.json"), "w") as f:
            json.dump(driver_knobs(args, splits), f, indent=1)

    remaining = args.rounds - session.round
    if remaining <= 0:
        print(f"checkpoint already at round {session.round} >= "
              f"--rounds {args.rounds}; nothing to train")
    else:
        # no --save-every but a checkpoint dir: save once at completion
        # (same segmentation on every rank; only process 0 writes files)
        save_every = (args.save_every or remaining) if ckpt_dir else 0
        t0 = time.time()
        session.train(remaining, local_epochs=args.local_epochs,
                      log_every=args.log_every,
                      save_every=save_every,
                      save_dir=ckpt_dir or None,
                      keep_last=args.keep_last)
        dt = time.time() - t0
        m = session.history[-1]
        print(f"trained {remaining} rounds in {dt:.1f}s "
              f"({remaining / dt:.2f} rounds/s)  "
              f"client_loss {m.client_loss:.4f}  "
              f"server_loss {m.server_loss:.4f}")
        if ckpt_dir and coordinator:
            print(f"checkpoints -> {ckpt_dir} "
                  f"(newest: round {session.round})")

    ev = session.evaluate(xt, yt, batch_size=512)
    ad = session.evaluate_adaptive(xt, yt, tau=args.tau, batch_size=512)
    for i, li in enumerate(splits):
        print(f"client {i} (l_i={li}): client_acc {ev['client_acc'][i]:.3f}  "
              f"server_acc {ev['server_acc'][i]:.3f}  "
              f"adaptive_acc {ad['acc'][i]:.3f} "
              f"(client_ratio {ad['client_ratio'][i]:.2f})")


if __name__ == "__main__":
    main()
