"""Paper Table-I ResNet-18 variant with selectable ``end_layer``.

Layer naming follows the paper exactly:
  Layer1 : stem conv (stride 1 for CIFAR, 2 otherwise)
  Layer2 : BasicBlock  64, stride 1
  Layer3 : BasicBlock  64, stride 1
  Layer4 : BasicBlock 128, stride 2
  Layer5 : BasicBlock 256, stride 2
  Layer6 : BasicBlock 512, stride 2
  head   : adaptive avg-pool + fc (the *server output layer*)
The client output layer (paper: avg-pool + fc at the cut) is
``init_client_head`` / ``client_head``.

Parameters are keyed ``layer1..layer6`` so the cross-layer aggregation of
Eq. (1) can identify common layers across heterogeneous server models by
name.  BatchNorm running statistics are threaded explicitly as ``state``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import fan_in_init, ones, zeros


@dataclass(frozen=True)
class ResNetConfig:
    num_classes: int = 10
    stem_stride: int = 1              # 1 for CIFAR, 2 for STL-10
    width_mult: float = 1.0           # reduced variants for smoke tests
    num_layers: int = 6               # paper L = 6
    image_size: int = 32
    bn_momentum: float = 0.9
    dtype: type = jnp.float32

    def channels(self) -> Tuple[int, ...]:
        base = [64, 64, 64, 128, 256, 512]
        return tuple(max(8, int(c * self.width_mult)) for c in base)

    def strides(self) -> Tuple[int, ...]:
        return (self.stem_stride, 1, 1, 2, 2, 2)


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def _conv(params, x, stride):
    return jax.lax.conv_general_dilated(
        x, params, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _init_conv(rng, k, cin, cout, dtype):
    return fan_in_init(rng, (k, k, cin, cout), dtype, fan_in=k * k * cin)


def _init_bn(c, dtype):
    return ({"scale": ones((c,), dtype), "bias": zeros((c,), dtype)},
            {"mean": zeros((c,), jnp.float32), "var": ones((c,), jnp.float32)})


def _bn(params, state, x, train: bool, momentum: float):
    if train:
        axes = (0, 1, 2)
        mean = jnp.mean(x, axes)
        var = jnp.var(x, axes)
        new_state = {
            "mean": momentum * state["mean"] + (1 - momentum) * mean,
            "var": momentum * state["var"] + (1 - momentum) * var,
        }
    else:
        mean, var = state["mean"], state["var"]
        new_state = state
    inv = jax.lax.rsqrt(var + 1e-5)
    out = (x - mean) * inv * params["scale"] + params["bias"]
    return out, new_state


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _init_basic_block(rng, cin, cout, dtype):
    ks = jax.random.split(rng, 3)
    p: dict = {"conv1": _init_conv(ks[0], 3, cin, cout, dtype),
               "conv2": _init_conv(ks[1], 3, cout, cout, dtype)}
    s: dict = {}
    p["bn1"], s["bn1"] = _init_bn(cout, dtype)
    p["bn2"], s["bn2"] = _init_bn(cout, dtype)
    if cin != cout:
        p["proj"] = _init_conv(ks[2], 1, cin, cout, dtype)
        p["bn_proj"], s["bn_proj"] = _init_bn(cout, dtype)
    return p, s


def _basic_block(p, s, x, stride, train, momentum):
    ns = {}
    h = _conv(p["conv1"], x, stride)
    h, ns["bn1"] = _bn(p["bn1"], s["bn1"], h, train, momentum)
    h = jax.nn.relu(h)
    h = _conv(p["conv2"], h, 1)
    h, ns["bn2"] = _bn(p["bn2"], s["bn2"], h, train, momentum)
    if "proj" in p:
        sc = _conv(p["proj"], x, stride)
        sc, ns["bn_proj"] = _bn(p["bn_proj"], s["bn_proj"], sc, train, momentum)
    else:
        sc = x if stride == 1 else x[:, ::stride, ::stride, :]
    return jax.nn.relu(h + sc), ns


# ---------------------------------------------------------------------------
# full network
# ---------------------------------------------------------------------------


def layer_names(cfg: ResNetConfig) -> Tuple[str, ...]:
    return tuple(f"layer{i + 1}" for i in range(cfg.num_layers))


def init_resnet(rng, cfg: ResNetConfig) -> Tuple[dict, dict]:
    """Returns (params, bn_state), keyed layer1..layerL plus 'head'."""
    chans, strides = cfg.channels(), cfg.strides()
    params: Dict[str, dict] = {}
    state: Dict[str, dict] = {}
    ks = jax.random.split(rng, cfg.num_layers + 1)
    # layer1: stem conv + bn
    p1: dict = {"conv": _init_conv(ks[0], 3, 3, chans[0], cfg.dtype)}
    s1: dict = {}
    p1["bn"], s1["bn"] = _init_bn(chans[0], cfg.dtype)
    params["layer1"], state["layer1"] = p1, s1
    cin = chans[0]
    for i in range(1, cfg.num_layers):
        p, s = _init_basic_block(ks[i], cin, chans[i], cfg.dtype)
        params[f"layer{i + 1}"], state[f"layer{i + 1}"] = p, s
        cin = chans[i]
    params["head"] = {"w": fan_in_init(ks[-1], (cin, cfg.num_classes), cfg.dtype),
                      "b": zeros((cfg.num_classes,), cfg.dtype)}
    return params, state


def resnet_features(params: dict, state: dict, x: jnp.ndarray,
                    cfg: ResNetConfig, *, start_layer: int = 0,
                    end_layer: Optional[int] = None, train: bool = False
                    ) -> Tuple[jnp.ndarray, dict]:
    """Run layers (start_layer, end_layer]; 1-indexed per the paper.
    ``start_layer=0, end_layer=3`` runs layer1..layer3 (a client net with
    l_i = 3); ``start_layer=3`` runs layer4..L (the matching server net)."""
    end_layer = end_layer or cfg.num_layers
    strides = cfg.strides()
    new_state = dict(state)
    h = x
    for i in range(start_layer, end_layer):
        name = f"layer{i + 1}"
        p, s = params[name], state[name]
        if i == 0:
            h = _conv(p["conv"], h, strides[0])
            h, ns_bn = _bn(p["bn"], s["bn"], h, train, cfg.bn_momentum)
            h = jax.nn.relu(h)
            new_state[name] = {"bn": ns_bn}
        else:
            h, ns = _basic_block(p, s, h, strides[i], train, cfg.bn_momentum)
            new_state[name] = ns
    return h, new_state


def head_forward(params: dict, feats: jnp.ndarray) -> jnp.ndarray:
    """Adaptive average pool + fc."""
    pooled = jnp.mean(feats, axis=(1, 2))
    return pooled @ params["w"] + params["b"]


def resnet_forward(params: dict, state: dict, x: jnp.ndarray,
                   cfg: ResNetConfig, *, end_layer: Optional[int] = None,
                   train: bool = False) -> Tuple[jnp.ndarray, dict]:
    feats, new_state = resnet_features(params, state, x, cfg,
                                       end_layer=end_layer, train=train)
    return head_forward(params["head"], feats), new_state


# ---------------------------------------------------------------------------
# client output layer (paper: avg-pool + fc after the cut layer)
# ---------------------------------------------------------------------------


def init_client_head(rng, cfg: ResNetConfig, end_layer: int) -> dict:
    cin = cfg.channels()[end_layer - 1]
    return {"w": fan_in_init(rng, (cin, cfg.num_classes), cfg.dtype),
            "b": zeros((cfg.num_classes,), cfg.dtype)}


client_head_forward = head_forward
