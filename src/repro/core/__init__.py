# Hetero-SplitEE core: the paper's contribution as composable JAX modules.
#   splitee.py      — split specs, per-client model partitioning
#   losses.py       — CE / entropy / confidence
#   aggregation.py  — Eq. (1) cross-layer aggregation
#   strategies.py   — Alg. 1 (Sequential) and Alg. 2 (Averaging), paper-faithful
#   fused.py        — scan+vmap multi-round engine (docs/ENGINES.md)
#   spmd.py         — fused SPMD production train step (masked exits + routing)
#   inference.py    — Alg. 3 entropy-gated adaptive inference
