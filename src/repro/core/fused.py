"""Fused multi-round training engine: scan + vmap, zero per-step host sync.

``FusedHeteroTrainer`` is a second execution backend for the Averaging /
distributed strategies of ``core/strategies.py``, built for throughput:

  * **Cohorts + vmap** — clients sharing a split layer ``l_i`` have identical
    pytree structure, so they are stacked along a leading lane axis
    (``splitee.stack_pytrees``) and their client+server steps run under one
    ``jax.vmap`` — one compiled step per *cohort*, not per client.
  * **Rounds under lax.scan** — ``run(rounds, local_epochs)`` pre-stages the
    exact minibatch sequence the reference engine would draw (same
    ``batch_iterator``, same seeds) as device-resident ``[rounds, E, k, B,
    ...]`` tensors and rolls the whole chunk into a ``jax.lax.scan`` with
    donated carry.  Losses come back as stacked per-round arrays at the end
    of a chunk — the reference engine's ``float(loss)`` sync per minibatch is
    gone.
  * **In-graph aggregation** — Eq. (1) cross-layer aggregation runs inside
    the scanned round body: a ``lax.cond`` on the traced
    ``(t+1) % aggregate_every == 0`` predicate applies
    ``stacked_cross_layer_aggregate`` on boundary rounds and the identity
    otherwise, so aggregation boundaries never leave the device and
    non-boundary rounds skip the means entirely.

The engine is numerically equivalent to ``HeteroTrainer`` (the paper-faithful
oracle) — both compose the same ``make_client_step`` / ``make_server_step``
builders — and the contract is enforced by ``tests/test_fused_engine.py``;
see docs/ENGINES.md.  The Sequential strategy (Alg. 1) is inherently ordered
across clients and stays on the reference engine.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import stacked_cross_layer_aggregate
from repro.core.splitee import stack_pytrees, unstack_pytrees
from repro.core.strategies import (HeteroTrainer, RoundMetrics,
                                   make_client_step, make_server_step)
from repro.data.pipeline import effective_batch_size, prestage_batches


class FusedHeteroTrainer(HeteroTrainer):
    """Drop-in replacement for ``HeteroTrainer`` (averaging / distributed)
    whose ``run`` executes whole chunks of rounds as one compiled program."""

    def __init__(self, model, splitee_cfg, opt_cfg, client_data, batch_size,
                 **kw):
        super().__init__(model, splitee_cfg, opt_cfg, client_data,
                         batch_size, **kw)
        if self.strategy not in ("averaging", "distributed"):
            raise ValueError(
                f"FusedHeteroTrainer supports averaging/distributed, not "
                f"{self.strategy!r}; the Sequential strategy is ordered "
                f"across clients — use HeteroTrainer.")
        splits = self.profile.split_layers
        self._cohort_lis: Tuple[int, ...] = tuple(sorted(set(splits)))
        self._lanes: Dict[int, List[int]] = {
            li: [i for i, l in enumerate(splits) if l == li]
            for li in self._cohort_lis}
        self._counts: Dict[int, int] = {li: len(v)
                                        for li, v in self._lanes.items()}
        # batch_iterator clamps short shards — lanes of one cohort are
        # stacked into a single [k, B, ...] tensor, so their effective batch
        # sizes must agree (the reference engine has no such constraint;
        # fail loudly here instead of inside np.stack)
        for li, lanes in self._lanes.items():
            bs = {i: effective_batch_size(len(client_data[i][0]), batch_size)
                  for i in lanes}
            if len(set(bs.values())) > 1:
                raise ValueError(
                    f"cohort l_i={li} mixes effective batch sizes {bs} "
                    f"(batch_size={batch_size} clamped to shard length); "
                    f"equalize client shards or use HeteroTrainer")
        self._chunk_fns: Dict[int, Callable] = {}

    # -------------------------------------------------------------- tracing
    def _vstep(self, li: int) -> Callable:
        """One cohort step: the shared client+server step builders composed
        exactly as the reference engine's ``train_round`` inner loop, then
        vmapped over the lane axis."""
        cstep = make_client_step(self.model, self.opt_cfg)
        sstep = make_server_step(self.model, self.opt_cfg, li)

        def combined(client, copt, server, sopt, x, y, lr, lr_s):
            tr, st, copt, h, closs = cstep(client["trainable"],
                                           client["state"], copt, x, y, lr)
            h = jax.lax.stop_gradient(h)      # no server->client gradient
            srv, sst, sopt, sloss = sstep(server["trainable"],
                                          server["state"], sopt, h, y, lr_s)
            return ({"trainable": tr, "state": st}, copt,
                    {"trainable": srv, "state": sst}, sopt, closs, sloss)

        return jax.vmap(combined, in_axes=(0, 0, 0, 0, 0, 0, None, None))

    def _chunk_fn(self, local_epochs: int) -> Callable:
        """Jitted ``(carry, ts, xs, ys) -> (carry, (closs[n], sloss[n]))``
        scanning the round body over a chunk; carry buffers are donated."""
        if local_epochs in self._chunk_fns:
            return self._chunk_fns[local_epochs]

        cohort_lis = self._cohort_lis
        counts = self._counts
        vsteps = {li: self._vstep(li) for li in cohort_lis}
        denom = float(self.N * local_epochs)
        averaging = self.strategy == "averaging"
        agg_every = self.cfg.aggregate_every
        schedule, lr_div = self.schedule, self.server_lr_div

        def epoch_body(carry, bx, by, lr, lr_s):
            out, csum, ssum = {}, 0.0, 0.0
            for li in cohort_lis:
                client, copt, server, sopt = carry[li]
                client, copt, server, sopt, closs, sloss = vsteps[li](
                    client, copt, server, sopt, bx[li], by[li], lr, lr_s)
                out[li] = (client, copt, server, sopt)
                csum = csum + jnp.sum(closs)
                ssum = ssum + jnp.sum(sloss)
            return out, (csum, ssum)

        def round_body(carry, inp):
            t, xs, ys = inp
            lr = schedule(t)
            lr_s = lr / lr_div

            def body(c, data):
                return epoch_body(c, data[0], data[1], lr, lr_s)

            carry, (cs, ss) = jax.lax.scan(body, carry, (xs, ys))
            if averaging:
                def aggregated(c):
                    tr = stacked_cross_layer_aggregate(
                        {li: c[li][2]["trainable"] for li in cohort_lis},
                        counts)
                    st = stacked_cross_layer_aggregate(
                        {li: c[li][2]["state"] for li in cohort_lis},
                        counts)
                    return {li: (c[li][0], c[li][1],
                                 {"trainable": tr[li], "state": st[li]},
                                 c[li][3])
                            for li in cohort_lis}

                # cond (not where) so non-boundary rounds skip the Eq. (1)
                # means entirely — still in-graph, still no host sync
                do = ((t + 1) % agg_every) == 0
                carry = jax.lax.cond(do, aggregated, lambda c: c, carry)
            return carry, (jnp.sum(cs) / denom, jnp.sum(ss) / denom)

        def chunk(carry, ts, xs, ys):
            return jax.lax.scan(round_body, carry, (ts, xs, ys))

        fn = jax.jit(chunk, donate_argnums=(0,))
        self._chunk_fns[local_epochs] = fn
        return fn

    # ------------------------------------------------------------- staging
    def _stage_chunk(self, rounds: int, local_epochs: int):
        """Draw the chunk's minibatches from the per-client iterators (the
        same sequence the reference engine would consume) and stack them as
        ``{li: [rounds, E, k, B, ...]}`` device arrays."""
        per_client = [prestage_batches(self.iters[i], rounds, local_epochs)
                      for i in range(self.N)]
        xs, ys = {}, {}
        for li in self._cohort_lis:
            lanes = self._lanes[li]
            xs[li] = jnp.asarray(np.stack([per_client[i][0] for i in lanes],
                                          axis=2))
            ys[li] = jnp.asarray(np.stack([per_client[i][1] for i in lanes],
                                          axis=2))
        return xs, ys

    def _stack_carry(self):
        carry = {}
        for li in self._cohort_lis:
            lanes = self._lanes[li]
            carry[li] = (
                self.model.stack_clients([self.clients[i] for i in lanes]),
                stack_pytrees([self.client_opts[i] for i in lanes]),
                self.model.stack_clients([self.servers[i] for i in lanes]),
                stack_pytrees([self.server_opts[i] for i in lanes]),
            )
        return carry

    def _unstack_carry(self, carry) -> None:
        for li in self._cohort_lis:
            lanes = self._lanes[li]
            clients, copts, servers, sopts = (
                unstack_pytrees(t, len(lanes)) for t in carry[li])
            for j, i in enumerate(lanes):
                self.clients[i] = clients[j]
                self.client_opts[i] = copts[j]
                self.servers[i] = servers[j]
                self.server_opts[i] = sopts[j]

    # ------------------------------------------------------------ training
    def train_round(self, local_epochs: int = 1) -> RoundMetrics:
        """Single fused round (one-round chunk); prefer ``run`` for chunks."""
        return self.run(1, local_epochs)[-1]

    def run(self, rounds: int, local_epochs: int = 1, log_every: int = 0,
            chunk_rounds: int = 0) -> List[RoundMetrics]:
        """Train ``rounds`` rounds.  ``chunk_rounds`` bounds how many rounds
        of pre-staged data are resident at once (0 = the whole run is one
        compiled chunk).  Host sync happens once per chunk."""
        chunk = chunk_rounds if chunk_rounds > 0 else rounds
        done = 0
        while done < rounds:
            n = min(chunk, rounds - done)
            self._run_chunk(n, local_epochs, log_every)
            done += n
        return self.history

    def _run_chunk(self, n: int, local_epochs: int, log_every: int) -> None:
        xs, ys = self._stage_chunk(n, local_epochs)
        ts = jnp.arange(self._round, self._round + n, dtype=jnp.int32)
        carry, (closs, sloss) = self._chunk_fn(local_epochs)(
            self._stack_carry(), ts, xs, ys)
        self._unstack_carry(carry)
        closs, sloss = np.asarray(closs), np.asarray(sloss)  # one sync
        for r in range(n):
            m = RoundMetrics(self._round + r, float(closs[r]),
                             float(sloss[r]))
            self.history.append(m)
            if log_every and (m.round % log_every == 0):
                print(f"round {m.round:4d}  client_loss {m.client_loss:.4f}"
                      f"  server_loss {m.server_loss:.4f}")
        self._round += n
