"""Synthetic datasets (offline container — no CIFAR/STL download possible).

``SyntheticImageDataset`` is a *procedural class-conditional* image task with
a difficulty knob: each class owns a random low-frequency prototype; a sample
is prototype + random shift + Gaussian noise, with the paper's augmentation
(4-px pad + random crop + horizontal flip) applied at batch time.  With more
classes the prototypes crowd the same subspace and accuracy drops — giving a
CIFAR-10-like "easy" task at 10 classes and a CIFAR-100-like "hard" task at
100 classes, which is what the paper's claims are *about* (collaboration
helps more as difficulty grows).  We validate orderings/gaps, not absolute
accuracies; see docs/EXPERIMENTS.md §Paper-validation.

``SyntheticLMDataset`` produces token streams with per-sequence affine
next-token structure (t_{i+1} = (a*t_i + b) mod V on 90%% of steps), which a
small transformer learns quickly — used by the end-to-end driver.

``SyntheticSeqClsDataset`` is the token-domain analogue of the image task:
each class owns a small set of signature tokens; a sequence mixes signature
draws with uniform noise and the label is the class id (< vocab), so a
backbone's last-position logits can be scored like an image classifier.
It feeds ``core.backbone_splitee.BackboneSplitModel`` through the same
``(x, y)`` per-client shard contract as the image datasets.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Tuple

import numpy as np


@dataclass
class SyntheticImageDataset:
    num_classes: int = 10
    image_size: int = 32
    train_size: int = 50_000
    test_size: int = 10_000
    noise: float = 0.9              # sample noise std (difficulty knob)
    proto_scale: float = 1.0
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        s = self.image_size
        # low-frequency prototypes: upsampled 8x8 random fields
        low = rng.normal(size=(self.num_classes, 8, 8, 3)).astype(np.float32)
        reps = s // 8
        self.prototypes = (np.repeat(np.repeat(low, reps, 1), reps, 2)
                           * self.proto_scale)
        self._train = self._make_split(rng, self.train_size)
        self._test = self._make_split(rng, self.test_size)

    def _make_split(self, rng, n) -> Tuple[np.ndarray, np.ndarray]:
        labels = rng.integers(0, self.num_classes, size=n).astype(np.int32)
        imgs = self.prototypes[labels].copy()
        # per-sample cyclic shift (makes the task non-template-matching)
        sh = rng.integers(0, 4, size=(n, 2))
        for axis in (0, 1):
            for k in range(1, 4):
                idx = sh[:, axis] == k
                imgs[idx] = np.roll(imgs[idx], k, axis=axis + 1)
        imgs += rng.normal(scale=self.noise, size=imgs.shape).astype(np.float32)
        return imgs, labels

    @property
    def train(self):
        return self._train

    @property
    def test(self):
        return self._test

    @staticmethod
    def augment(rng: np.random.Generator, imgs: np.ndarray) -> np.ndarray:
        """Paper augmentation: zero-pad 4px, random crop, random hflip."""
        n, h, w, c = imgs.shape
        padded = np.pad(imgs, ((0, 0), (4, 4), (4, 4), (0, 0)))
        out = np.empty_like(imgs)
        ys = rng.integers(0, 9, size=n)
        xs = rng.integers(0, 9, size=n)
        flips = rng.random(n) < 0.5
        for i in range(n):
            crop = padded[i, ys[i] : ys[i] + h, xs[i] : xs[i] + w]
            out[i] = crop[:, ::-1] if flips[i] else crop
        return out


@dataclass
class SyntheticLMDataset:
    vocab_size: int = 32_000
    seq_len: int = 256
    seed: int = 0
    structure: float = 0.9          # fraction of affine next-token steps

    def batches(self, batch_size: int, num_batches: int
                ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        rng = np.random.default_rng(self.seed)
        V, T = self.vocab_size, self.seq_len
        for _ in range(num_batches):
            a = rng.integers(1, 64, size=(batch_size, 1))
            b = rng.integers(0, V, size=(batch_size, 1))
            toks = np.empty((batch_size, T + 1), np.int64)
            toks[:, 0] = rng.integers(0, V, size=batch_size)
            for t in range(T):
                nxt = (a[:, 0] * toks[:, t] + b[:, 0]) % V
                noise = rng.integers(0, V, size=batch_size)
                use_noise = rng.random(batch_size) > self.structure
                toks[:, t + 1] = np.where(use_noise, noise, nxt)
            yield toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)


@dataclass
class SyntheticSeqClsDataset:
    """Class-conditional token sequences for sequence classification.

    Class ``c`` owns ``signature`` random vocabulary tokens; each position is
    a signature draw with probability ``p_signal`` and uniform noise
    otherwise.  Labels are class ids in ``[0, num_classes)`` — a strict
    subset of the vocabulary, so V-way logits (an LM/exit head) score them
    directly.  Difficulty is controlled by ``p_signal`` and ``num_classes``.
    """

    vocab_size: int
    seq_len: int = 16
    num_classes: int = 8
    train_size: int = 512
    test_size: int = 256
    signature: int = 8              # signature tokens per class
    p_signal: float = 0.5           # per-position probability of a signature
    seed: int = 0

    def __post_init__(self):
        assert self.num_classes <= self.vocab_size
        rng = np.random.default_rng(self.seed)
        self.signatures = rng.integers(
            0, self.vocab_size, size=(self.num_classes, self.signature))
        self._train = self._make_split(rng, self.train_size)
        self._test = self._make_split(rng, self.test_size)

    def _make_split(self, rng, n) -> Tuple[np.ndarray, np.ndarray]:
        labels = rng.integers(0, self.num_classes, size=n).astype(np.int32)
        pick = rng.integers(0, self.signature, size=(n, self.seq_len))
        sig = self.signatures[labels[:, None], pick]
        noise = rng.integers(0, self.vocab_size, size=(n, self.seq_len))
        use_sig = rng.random((n, self.seq_len)) < self.p_signal
        toks = np.where(use_sig, sig, noise).astype(np.int32)
        return toks, labels

    @property
    def train(self):
        return self._train

    @property
    def test(self):
        return self._test
