"""Fused SPMD Hetero-SplitEE step: gradient routing, Eq.-(1) scaling trees,
both grad modes, and learnability."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (HeteroProfile, ModelConfig, OptimizerConfig,
                          SplitEEConfig, TrainConfig)
from repro.core.spmd import (StepConfig, boundary_ids_for_batch,
                             make_serve_step, make_train_step,
                             participation_scale_trees)
from repro.models.backbone import init_backbone, init_cache
from repro.optim import adam_init


def _sc(cfg, splits, grad_mode="eq1", lr=1e-3, steps=200):
    return StepConfig(
        model=cfg,
        splitee=SplitEEConfig(profile=HeteroProfile(splits)),
        train=TrainConfig(optimizer=OptimizerConfig(lr=lr, total_steps=steps)),
        grad_mode=grad_mode)


def _batch(cfg, profile, B=8, T=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    return {
        "tokens": jax.random.randint(ks[0], (B, T), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, T), 0, cfg.vocab_size),
        "split_ids": boundary_ids_for_batch(profile, cfg, B),
    }


def test_boundary_ids(tiny_dense):
    prof = HeteroProfile((1, 1, 2, 2))
    ids = boundary_ids_for_batch(prof, tiny_dense, 8)
    assert ids.shape == (8,)
    # exits (1, 2) -> boundary indices 0 and 1; groups tile the batch
    np.testing.assert_array_equal(np.asarray(ids),
                                  [0, 0, 0, 0, 1, 1, 1, 1])


def test_scale_trees_values(tiny_dense):
    cfg = tiny_dense
    params = init_backbone(jax.random.PRNGKey(0), cfg)
    prof = HeteroProfile((1, 1, 2, 2))
    cs, ss = participation_scale_trees(params, cfg, prof)
    # embedding: all 4 groups' exit losses reach it -> 1/4; server never
    emb_c = jax.tree.leaves(cs["embed"])[0]
    emb_s = jax.tree.leaves(ss["embed"])[0]
    assert float(emb_c) == pytest.approx(0.25)
    assert float(emb_s) == 0.0
    # final head: server family only, all groups -> 1/4
    assert float(jax.tree.leaves(cs["head"])[0]) == 0.0
    assert float(jax.tree.leaves(ss["head"])[0]) == pytest.approx(0.25)
    # exit head at boundary 0: trained by the 2 groups cut there -> 1/2
    assert float(jax.tree.leaves(cs["exit_heads"][0])[0]) == pytest.approx(0.5)
    # layer participation: layer0 client-side for all 4 (1/4, s=0);
    # layer1 client-side for the two l=2 groups (1/2), server for l=1 (1/2)
    nc0 = jax.tree.leaves(cs["segments"][0])[0]
    assert float(np.ravel(nc0)[0]) == pytest.approx(0.25)
    nc1 = jax.tree.leaves(cs["segments"][1])[0]
    ns1 = jax.tree.leaves(ss["segments"][1])[0]
    assert float(np.ravel(nc1)[0]) == pytest.approx(0.5)
    assert float(np.ravel(ns1)[0]) == pytest.approx(0.5)
    # last segment (layers 2,3): server-only (1/2 for l>=2... layer2: groups
    # with split<=2 = all 4? splits are (1,1,2,2): layer2 server for all -> 1/4
    ns2 = jax.tree.leaves(ss["segments"][2])[0]
    assert float(np.ravel(ns2)[0]) == pytest.approx(0.25)


@pytest.mark.parametrize("grad_mode", ["eq1", "sum"])
def test_train_step_runs_and_learns(tiny_dense, grad_mode):
    cfg = tiny_dense
    prof = HeteroProfile((1, 1, 2, 2))
    sc = _sc(cfg, (1, 1, 2, 2), grad_mode=grad_mode, lr=5e-3)
    params = init_backbone(jax.random.PRNGKey(0), cfg)
    opt = adam_init(params, sc.train.optimizer)
    step = jax.jit(make_train_step(sc))
    batch = _batch(cfg, prof)           # fixed batch -> loss must drop fast
    first = None
    for _ in range(30):
        params, opt, m = step(params, opt, batch)
        if first is None:
            first = float(m["server_loss"])
    last = float(m["server_loss"])
    assert last < first * 0.7, (first, last)
    assert all(np.isfinite(float(v)) for v in m.values())


def test_eq1_mode_matches_per_family_grads(tiny_dense):
    """eq1 grads == (client_grads * cs + server_grads * ss) computed by two
    independent jax.grad calls."""
    cfg = tiny_dense
    prof = HeteroProfile((1, 2, 2, 2))
    sc = _sc(cfg, (1, 2, 2, 2))
    params = init_backbone(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, prof)

    from repro.core.spmd import hetero_losses
    from repro.models.backbone import backbone_forward

    def closs(p):
        out = backbone_forward(p, cfg, tokens=batch["tokens"],
                               split_ids=batch["split_ids"])
        c, s, _ = hetero_losses(out, batch["labels"], batch["split_ids"], 2)
        return c

    def sloss(p):
        out = backbone_forward(p, cfg, tokens=batch["tokens"],
                               split_ids=batch["split_ids"])
        c, s, _ = hetero_losses(out, batch["labels"], batch["split_ids"], 2)
        return s

    gc = jax.grad(closs)(params)
    gs = jax.grad(sloss)(params)
    cs, ss = participation_scale_trees(params, cfg, prof)
    expected = jax.tree.map(lambda a, b, x, y: a * x + b * y, gc, gs, cs, ss)

    # one eq1 step with lr=0 Adam? simpler: recompute via the internal path
    opt = adam_init(params, sc.train.optimizer)
    step = make_train_step(sc)
    new_params, _, _ = step(params, opt, batch)
    # Adam step direction check on one leaf: sign of update matches -grad
    leaf = params["head"]["w"]
    new_leaf = new_params["head"]["w"]
    exp_leaf = jax.tree.leaves(expected["head"])  # norm + w
    # head grad comes only through server family; nonzero somewhere
    assert float(sum(jnp.abs(g).sum() for g in exp_leaf)) > 0
    assert not np.allclose(np.asarray(leaf), np.asarray(new_leaf))


def test_serve_step_gate(tiny_dense):
    cfg = tiny_dense
    sc = _sc(cfg, (1, 1, 2, 2))
    sc = dataclasses.replace(
        sc, splitee=dataclasses.replace(sc.splitee, entropy_threshold=100.0))
    params = init_backbone(jax.random.PRNGKey(0), cfg)
    cache = init_cache(cfg, 4, 8, jnp.float32)
    serve = jax.jit(make_serve_step(sc, boundary=0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 1), 0, cfg.vocab_size)
    out = serve(params, toks, cache, jnp.zeros((), jnp.int32))
    # tau=100 -> everything exits; logits must equal the boundary-0 exit head
    assert bool(np.asarray(out["exited"]).all())
    assert out["logits"].shape == (4, 1, cfg.vocab_size)
    sc2 = dataclasses.replace(
        sc, splitee=dataclasses.replace(sc.splitee, entropy_threshold=0.0))
    out2 = jax.jit(make_serve_step(sc2, boundary=0))(
        params, toks, cache, jnp.zeros((), jnp.int32))
    assert not bool(np.asarray(out2["exited"]).any())


def test_sequential_spmd_step(tiny_dense):
    """Extension: Alg. 1 as a lax.scan over client groups inside one jit."""
    from repro.core.spmd import make_sequential_train_step
    cfg = tiny_dense
    prof = HeteroProfile((1, 1, 2, 2))
    sc = _sc(cfg, (1, 1, 2, 2), lr=5e-3)
    params = init_backbone(jax.random.PRNGKey(0), cfg)
    opt = adam_init(params, sc.train.optimizer)
    step = jax.jit(make_sequential_train_step(sc))
    batch = _batch(cfg, prof)
    first = None
    for _ in range(15):
        params, opt, m = step(params, opt, batch)
        if first is None:
            first = float(m["server_loss"])
    assert np.isfinite(float(m["server_loss"]))
    assert float(m["server_loss"]) < first
    # N groups -> opt stepped N times per call
    assert int(opt.step) == 15 * 4
