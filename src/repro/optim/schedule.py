"""Learning-rate schedules.  Paper Table II: cosine annealing from
eta_max = 1e-3 to eta_min = 1e-6 over T_max = 600 epochs, no warmup."""
from __future__ import annotations

import jax.numpy as jnp

from repro.config import OptimizerConfig


def cosine_schedule(step, base_lr: float, min_lr: float, total_steps: int,
                    warmup_steps: int = 0):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / jnp.maximum(1.0, warmup_steps)
    t = jnp.clip((step - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps),
                 0.0, 1.0)
    cos = min_lr + 0.5 * (base_lr - min_lr) * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup_steps, warm, cos)


def make_schedule(cfg: OptimizerConfig):
    if cfg.schedule == "cosine":
        return lambda step: cosine_schedule(step, cfg.lr, cfg.min_lr,
                                            cfg.total_steps, cfg.warmup_steps)
    if cfg.schedule == "constant":
        return lambda step: jnp.full((), cfg.lr, jnp.float32)
    raise ValueError(cfg.schedule)
