"""Configuration dataclasses for the repro framework.

Everything the launcher, the models and the Hetero-SplitEE core consume is
described by the frozen dataclasses below.  Configs are plain data — hashable,
printable, and safe to close over in jit'd functions.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Sub-configs for architecture families
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration."""

    num_experts: int
    top_k: int
    d_expert: int                       # hidden dim of each routed expert
    num_shared_experts: int = 0         # DeepSeek-style always-on shared expert(s)
    d_shared_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001    # load-balance loss weight
    router_dtype: Any = jnp.float32


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek Multi-head Latent Attention configuration."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """State-space / linear-attention block configuration (Mamba2, RWKV6)."""

    kind: str = "mamba2"               # "mamba2" | "rwkv6"
    d_state: int = 64                  # SSM state dim per head
    d_conv: int = 4                    # depthwise conv width (mamba)
    expand: int = 2                    # inner expansion factor
    head_dim: int = 64                 # SSD head dim
    chunk_size: int = 256              # chunked-scan block length


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """One architecture.  ``block_pattern`` gives the per-layer block kind;
    it has length ``num_layers`` and entries in
    {"attn", "mla", "mamba2", "rwkv6", "shared_attn"} for the mixer and the
    FFN kind is chosen by ``ffn_pattern`` entries in {"mlp", "moe", "none"}.
    """

    name: str
    arch_type: str                     # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // num_heads
    block_pattern: Tuple[str, ...] = ()    # defaults to all-"attn"
    ffn_pattern: Tuple[str, ...] = ()      # defaults to all-"mlp"
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rope_theta: float = 10000.0
    use_qkv_bias: bool = False
    use_mlp_bias: bool = False
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    sliding_window: Optional[int] = None   # tokens; None = full attention
    act: str = "silu"                  # mlp activation: silu (SwiGLU) | gelu
    cross_attention: bool = False      # enc-dec decoder (whisper)
    cross_source_len: int = 1500       # design-limit source length (whisper)
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.bfloat16
    # kernel backend for the routed hot sites (attention, wkv, entropy
    # gate): "auto" = pallas on TPU / ref elsewhere; see
    # repro.kernels.dispatch
    kernels: str = "auto"
    # --- Hetero-SplitEE ---
    exit_layers: Tuple[int, ...] = ()  # layers after which an exit head sits
    # citation for the assigned-architecture pool
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if not self.block_pattern:
            object.__setattr__(self, "block_pattern", ("attn",) * self.num_layers)
        if not self.ffn_pattern:
            object.__setattr__(self, "ffn_pattern", ("mlp",) * self.num_layers)
        assert len(self.block_pattern) == self.num_layers, self.name
        assert len(self.ffn_pattern) == self.num_layers, self.name
        for l in self.exit_layers:
            assert 0 < l < self.num_layers, f"exit layer {l} out of range"
        assert self.kernels in ("auto", "pallas", "ref"), \
            f"{self.name}: kernels={self.kernels!r}"

    # -- derived ----------------------------------------------------------
    @property
    def q_heads_per_kv(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    def segments(self) -> Tuple[Tuple[int, int], ...]:
        """Contiguous [start, end) layer ranges delimited by exit layers."""
        bounds = [0, *sorted(self.exit_layers), self.num_layers]
        return tuple((bounds[i], bounds[i + 1]) for i in range(len(bounds) - 1))

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Hetero-SplitEE configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HeteroProfile:
    """Assignment of split points to client groups.

    ``split_layers[g]`` is the cut layer l_i of client group ``g``.  In the
    SPMD production step, group ``g`` owns the ``g``-th equal slice of the
    ``data`` mesh axis.  In the paper-scale engines each entry is one client.
    """

    split_layers: Tuple[int, ...]

    @property
    def num_groups(self) -> int:
        return len(self.split_layers)

    @property
    def distinct_splits(self) -> Tuple[int, ...]:
        return tuple(sorted(set(self.split_layers)))

    def participation(self, layer: int) -> Tuple[int, ...]:
        """Eq. (1) participation set over 0-indexed layers:
        ``C_l = {i : l_i <= l}`` — layer ``l`` is *server-side* for client i
        iff ``l_i <= l``, since client i holds layers ``[0, l_i)``.  (The
        paper writes ``C_l = {i : l_i < l}`` with 1-indexed ``l``; both
        describe the same set, and a client sitting exactly at the boundary
        ``l_i == l`` participates.)"""
        return tuple(i for i, li in enumerate(self.split_layers) if li <= layer)


@dataclass(frozen=True)
class SplitEEConfig:
    """Hetero-SplitEE training configuration (paper §III)."""

    profile: HeteroProfile
    strategy: str = "averaging"        # "sequential" | "averaging"
    server_lr_divisor: float = 0.0     # 0 -> auto: N for sequential, 1 for avg
    aggregate_every: int = 1           # rounds between cross-layer aggregations
    entropy_threshold: float = 1.0     # exit iff H < tau_H  (see docs/DESIGN.md §1)

    def resolved_server_lr_divisor(self) -> float:
        if self.server_lr_divisor > 0:
            return self.server_lr_divisor
        return float(self.profile.num_groups) if self.strategy == "sequential" else 1.0


# ---------------------------------------------------------------------------
# Training / optimizer config (paper Table II defaults)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adam"
    lr: float = 1e-3                   # eta_max
    min_lr: float = 1e-6               # eta_min
    schedule: str = "cosine"           # cosine annealing, warmup 0
    warmup_steps: int = 0
    total_steps: int = 600
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    state_dtype: Any = jnp.float32     # Adam m/v dtype (bf16 for huge models)
    grad_clip: float = 0.0             # 0 = off


@dataclass(frozen=True)
class TrainConfig:
    batch_size: int = 1024
    seq_len: int = 0                   # 0 for image models
    global_rounds: int = 600
    local_epochs: int = 1
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    remat: str = "none"                # none | full | dots_saveable
    seed: int = 0


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # "train" | "prefill" | "decode"


INPUT_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in INPUT_SHAPES}


# ---------------------------------------------------------------------------
# Mesh config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> Tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
