"""``TrainSession`` — the one front door for Hetero-SplitEE training.

A session binds a :class:`~repro.api.protocol.SplitModel` adapter, the
paper's configuration dataclasses, per-client data shards, and a registered
engine; all mutable progress lives in one immutable
:class:`~repro.api.state.TrainState` pytree that the engine consumes and
returns.  Because the state is a plain pytree, a session can be saved,
restored, and handed between engines with a resume-equivalence guarantee:
training 2k rounds equals training k, saving, restoring, and training k —
on parameters, Adam moments, BN statistics, and per-round metrics
(tests/test_session.py).

    session = TrainSession.from_config(model, splitee_cfg, opt_cfg,
                                       client_data, batch_size=64,
                                       engine="auto")
    session.train(rounds=100)
    session.save("ckpt/run1")
    ...
    session = TrainSession.restore("ckpt/run1", model, client_data)
    session.train(rounds=100)            # continues round 100..199
    session.evaluate(x_test, y_test)

See docs/API.md for the full lifecycle and the checkpoint layout.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.api import fused_engine as _fused_engine      # noqa: F401 (registers)
from repro.api import reference_engine as _reference_engine  # noqa: F401
from repro.api.engines import SessionContext, resolve_engine
from repro.api.evaluation import SplitEvaluator
from repro.api.protocol import assert_split_model
from repro.api.state import TrainState, init_train_state
from repro.checkpoint import load_pytree, save_pytree
from repro.config import HeteroProfile, OptimizerConfig, SplitEEConfig
from repro.core.strategies import RoundMetrics

#: checkpoint manifest format version (bump on layout changes)
CHECKPOINT_FORMAT = 1


class TrainSession:
    """Facade over (model adapter, configs, data, engine, TrainState)."""

    def __init__(self, model, splitee_cfg: SplitEEConfig,
                 opt_cfg: OptimizerConfig,
                 client_data: Sequence[Tuple[np.ndarray, np.ndarray]],
                 batch_size: int, *, engine: str = "auto",
                 augment=None, seed: int = 0,
                 state: Optional[TrainState] = None,
                 history: Optional[List[RoundMetrics]] = None):
        assert_split_model(model)
        self.ctx = SessionContext(model, splitee_cfg, opt_cfg, client_data,
                                  batch_size, augment=augment, seed=seed)
        self.engine = resolve_engine(engine, self.ctx)(self.ctx)
        self.state = (state if state is not None
                      else init_train_state(model, splitee_cfg, opt_cfg))
        self.history: List[RoundMetrics] = list(history or [])
        self._evaluator = SplitEvaluator(model, self.ctx.profile,
                                         self.ctx.strategy)

    @classmethod
    def from_config(cls, model, splitee_cfg: SplitEEConfig,
                    opt_cfg: OptimizerConfig,
                    data: Sequence[Tuple[np.ndarray, np.ndarray]],
                    batch_size: int = 64, *, engine: str = "auto",
                    augment=None, seed: int = 0) -> "TrainSession":
        """The canonical constructor (same arguments as ``__init__``; named
        for symmetry with ``restore``)."""
        return cls(model, splitee_cfg, opt_cfg, data, batch_size,
                   engine=engine, augment=augment, seed=seed)

    # ---------------------------------------------------------- properties
    @property
    def model(self):
        return self.ctx.model

    @property
    def round(self) -> int:
        """Global rounds completed so far."""
        return int(self.state.round)

    @property
    def engine_name(self) -> str:
        return self.engine.name

    # ------------------------------------------------------------ training
    def train(self, rounds: int, local_epochs: int = 1, log_every: int = 0,
              chunk_rounds: int = 0) -> List[RoundMetrics]:
        """Advance the state by ``rounds`` rounds; returns the new rounds'
        metrics (also appended to ``self.history``)."""
        self.state, metrics = self.engine.run(
            self.state, rounds, local_epochs=local_epochs,
            log_every=log_every, chunk_rounds=chunk_rounds)
        self.history.extend(metrics)
        return metrics

    def run(self, rounds: int, local_epochs: int = 1, log_every: int = 0,
            chunk_rounds: int = 0) -> List[RoundMetrics]:
        """Back-compat alias for :meth:`train` returning the full history
        (the old ``HeteroTrainer.run`` contract)."""
        self.train(rounds, local_epochs, log_every, chunk_rounds)
        return self.history

    # ---------------------------------------------------------- evaluation
    def evaluate(self, x, y, batch_size: int = 512) -> Dict[str, Any]:
        return self._evaluator.evaluate(self.state, x, y, batch_size)

    def evaluate_adaptive(self, x, y, tau: float, batch_size: int = 512
                          ) -> Dict[str, Any]:
        return self._evaluator.evaluate_adaptive(self.state, x, y, tau,
                                                 batch_size)

    # -------------------------------------------------------- checkpointing
    def save(self, path: str) -> None:
        """Write ``path + '.npz'`` (the full TrainState pytree) and
        ``path + '.json'`` (structure manifest + session metadata).  The
        model adapter and the data shards are NOT serialized — pass the
        same ones to :meth:`restore`."""
        opt = dataclasses.asdict(self.ctx.opt_cfg)
        opt["state_dtype"] = jnp.dtype(opt["state_dtype"]).name
        meta = {
            "format": CHECKPOINT_FORMAT,
            "kind": "train_session",
            "engine": self.engine.name,
            "splitee": {
                "split_layers": list(self.ctx.profile.split_layers),
                "strategy": self.ctx.cfg.strategy,
                "server_lr_divisor": self.ctx.cfg.server_lr_divisor,
                "aggregate_every": self.ctx.cfg.aggregate_every,
                "entropy_threshold": self.ctx.cfg.entropy_threshold,
            },
            "optimizer": opt,
            "batch_size": self.ctx.batch_size,
            "seed": self.ctx.seed,
            # the augment callable itself is not serializable, but whether
            # one was active is: the data replay diverges if it differs
            "augmented": self.ctx.augment is not None,
            "round": self.round,
            "history": [dataclasses.asdict(m) for m in self.history],
        }
        save_pytree(path, self.state, metadata=meta)

    @classmethod
    def restore(cls, path: str, model,
                client_data: Sequence[Tuple[np.ndarray, np.ndarray]],
                *, engine: Optional[str] = None, augment=None
                ) -> "TrainSession":
        """Rebuild a session from :meth:`save` output.  Configuration comes
        from the manifest; ``model`` and ``client_data`` must be the ones
        the run was built with (the state carries every learned tensor, the
        adapter only its architecture/seed).  ``engine`` overrides the saved
        engine name — a state saved by one engine restores into any other
        that supports the strategy."""
        with open(path + ".json") as f:
            meta = json.load(f)["metadata"]
        if meta.get("kind") != "train_session":
            raise ValueError(f"{path} is not a TrainSession checkpoint")
        if meta.get("format") != CHECKPOINT_FORMAT:
            raise ValueError(
                f"{path} has checkpoint format {meta.get('format')!r}; this "
                f"version reads format {CHECKPOINT_FORMAT}")
        if meta["augmented"] != (augment is not None):
            raise ValueError(
                f"checkpoint was saved with augment "
                f"{'active' if meta['augmented'] else 'inactive'} but "
                f"restore got augment={augment!r}; the replayed data stream "
                f"would diverge — pass the original augment function")
        sp = meta["splitee"]
        splitee_cfg = SplitEEConfig(
            profile=HeteroProfile(tuple(sp["split_layers"])),
            strategy=sp["strategy"],
            server_lr_divisor=sp["server_lr_divisor"],
            aggregate_every=sp["aggregate_every"],
            entropy_threshold=sp["entropy_threshold"])
        opt = dict(meta["optimizer"])
        opt["state_dtype"] = jnp.dtype(opt["state_dtype"])
        opt_cfg = OptimizerConfig(**opt)
        session = cls(model, splitee_cfg, opt_cfg, client_data,
                      meta["batch_size"], engine=engine or meta["engine"],
                      augment=augment, seed=meta["seed"])
        # fresh init has the identical pytree structure: restore into it
        session.state = load_pytree(path, session.state)
        session.history = [RoundMetrics(**m) for m in meta["history"]]
        return session
