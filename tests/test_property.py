"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dep: property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.config import HeteroProfile, OptimizerConfig
from repro.core.aggregation import (cross_layer_aggregate,
                                    participation_counts)
from repro.core.inference import exit_decision
from repro.core.losses import softmax_cross_entropy, softmax_entropy
from repro.data.pipeline import ClientPartitioner
from repro.optim.schedule import cosine_schedule

SETTINGS = dict(max_examples=25, deadline=None)


@given(st.lists(st.integers(1, 5), min_size=2, max_size=6),
       st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_eq1_aggregation_matches_loop_oracle(splits, seed):
    """For random split assignments and random params, the framework's
    aggregation equals a literal per-layer mean over C_l."""
    L = 6
    rng = np.random.default_rng(seed)
    models = []
    for li in splits:
        m = {f"layer{l}": {"w": jnp.array(rng.normal(size=(3,)), jnp.float32)}
             for l in range(li + 1, L + 1)}
        m["head"] = {"w": jnp.array(rng.normal(size=(3,)), jnp.float32)}
        models.append(m)
    out = cross_layer_aggregate(models, splits)

    for l in range(1, L + 1):
        key = f"layer{l}"
        members = [i for i, li in enumerate(splits) if li < l]
        if not members:
            continue
        mean = np.mean([np.asarray(models[i][key]["w"]) for i in members],
                       axis=0)
        for i in members:
            np.testing.assert_allclose(np.asarray(out[i][key]["w"]), mean,
                                       atol=1e-5)
    # non-members keep structure: no layer appears that wasn't there
    for i, li in enumerate(splits):
        assert set(out[i].keys()) == set(models[i].keys())


@given(st.lists(st.integers(1, 5), min_size=1, max_size=8))
@settings(**SETTINGS)
def test_participation_counts_partition(splits):
    nc, ns = participation_counts(splits, num_layers=6)
    for l in range(6):
        assert nc[l] + ns[l] == len(splits)
        assert nc[l] == sum(1 for s in splits if l < s)


@given(st.integers(0, 2 ** 16), st.floats(0.1, 3.9))
@settings(**SETTINGS)
def test_exit_decision_monotone_in_tau(seed, tau):
    """Exit sets grow monotonically with tau: exits(tau) ⊆ exits(tau+d)."""
    rng = np.random.default_rng(seed)
    logits = jnp.array(rng.normal(size=(16, 10)) * 2, jnp.float32)
    lo = np.asarray(exit_decision(logits, tau))
    hi = np.asarray(exit_decision(logits, tau + 0.5))
    assert np.all(hi[lo])                      # lo exits is a subset


@given(st.integers(2, 20))
@settings(**SETTINGS)
def test_entropy_bounds(classes):
    rng = np.random.default_rng(classes)
    logits = jnp.array(rng.normal(size=(8, classes)) * 3, jnp.float32)
    H = np.asarray(softmax_entropy(logits))
    assert np.all(H >= -1e-5)
    assert np.all(H <= np.log(classes) + 1e-5)
    # uniform logits -> max entropy
    Hu = float(softmax_entropy(jnp.zeros((1, classes)))[0])
    assert abs(Hu - np.log(classes)) < 1e-5


@given(st.integers(1, 12), st.integers(50, 300), st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_partitioner_covers_all_samples_once(n_clients, n, seed):
    x = np.arange(n)[:, None].astype(np.float32)
    y = np.arange(n).astype(np.int32)
    shards = ClientPartitioner(n_clients, seed=seed).split(x, y)
    seen = np.concatenate([s[1] for s in shards])
    assert sorted(seen.tolist()) == list(range(n))
    sizes = [len(s[1]) for s in shards]
    assert max(sizes) - min(sizes) <= 1        # near-uniform


@given(st.integers(1, 1000), st.integers(2, 2000))
@settings(**SETTINGS)
def test_cosine_schedule_bounds(step, total):
    lr = float(cosine_schedule(step, 1e-3, 1e-6, total))
    assert 1e-6 - 1e-9 <= lr <= 1e-3 + 1e-9
    # endpoint values (paper Table II), fp32 precision
    assert abs(float(cosine_schedule(0, 1e-3, 1e-6, total)) - 1e-3) < 1e-9
    assert abs(float(cosine_schedule(total, 1e-3, 1e-6, total)) - 1e-6) < 1e-9


@given(st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_masked_ce_matches_subset_ce(seed):
    rng = np.random.default_rng(seed)
    logits = jnp.array(rng.normal(size=(10, 7)), jnp.float32)
    labels = jnp.array(rng.integers(0, 7, 10), jnp.int32)
    mask = jnp.array(rng.integers(0, 2, 10), jnp.float32)
    if float(mask.sum()) == 0:
        return
    full = float(softmax_cross_entropy(logits, labels, mask))
    idx = np.nonzero(np.asarray(mask))[0]
    sub = float(softmax_cross_entropy(logits[idx], labels[idx]))
    assert abs(full - sub) < 1e-5
